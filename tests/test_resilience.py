"""Fault-tolerant execution tests (DESIGN.md §13).

Five layers, mirroring the resilience contract:

  * chaos registry — arm/fire/disarm one-shots, seeded schedules, env
    back-compat with ``HPTMT_SPILL_FAULT``;
  * retry policy — deterministic backoff, typed fatal-vs-transient
    split, budget exhaustion as :class:`RetryBudgetExceeded`;
  * hardened IO — typed :class:`CorruptFragmentError` for inconsistent
    ``.hpt`` headers, scan quarantine with sidecar manifest, checkpoint
    manifest CRC/dtype validation;
  * workflow — policy-routed retries, fatal fail-fast, journal content
    hashes that refuse a stale-DAG resume;
  * lineage stage checkpoints — fingerprinted commit/restore round
    trips, bit-exact resumed collects, suffix-only re-execution
    (jaxpr-asserted in the 4-device leg), and a real SIGKILL
    kill-and-resume subprocess.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import SRC

import jax
import jax.numpy as jnp

from repro import telemetry as T
from repro.checkpoint.manager import (CheckpointIntegrityError,
                                      CheckpointManager)
from repro.core import local_context
from repro.dataframe.frame import DataFrame
from repro.io.dataset import write_dataset
from repro.io.native import (CorruptFragmentError, HptIntegrityError,
                             read_hpt, write_hpt)
from repro.io.scan import pred
from repro.plan.frame import LazyFrame
from repro.resilience import (FatalInjectedFault, FaultPolicy,
                              InjectedFault, RetryBudgetExceeded,
                              StageCheckpointer, arm, arm_schedule, fires,
                              plan_fingerprint, reset)
from repro.resilience import faults
from repro.workflow.engine import Task, WorkflowEngine, WorkflowError


@pytest.fixture(autouse=True)
def _clean_faults():
    reset()
    yield
    reset()


def _dataset(tmp_path, n=64, name="ds"):
    rng = np.random.default_rng(3)
    cols = {"a": np.arange(n, dtype=np.float32),
            "b": (np.arange(n) % 8).astype(np.float32),
            "c": rng.normal(size=n).astype(np.float32)}
    root = str(tmp_path / name)
    write_dataset(root, [(cols, n)], format="hpt", rows_per_group=8)
    return root


def _pipeline(path, ctx, **kw):
    return (LazyFrame.read_parquet(path, ctx, **kw)
            .filter([pred("a", "<", 48.0)])
            .groupby(["b"], [("c", "sum"), ("c", "count")])
            .sort_values("b"))


def _rows(df):
    return {k: np.asarray(v) for k, v in df.to_numpy().items()}


# ---------------------------------------------------------------------------
# chaos registry
# ---------------------------------------------------------------------------
def test_arm_counts_down_fires_once_then_disarms():
    arm("scan.read", "io_error", nth=2)
    faults.fire("scan.read")                    # 1st occurrence: counts down
    with pytest.raises(InjectedFault):
        faults.fire("scan.read")                # 2nd: fires
    faults.fire("scan.read")                    # disarmed: clean no-op
    assert fires("scan.read") == 1 and fires() == 1


def test_fault_kinds_map_to_exception_families(tmp_path):
    arm("x", "fatal")
    with pytest.raises(FatalInjectedFault):
        faults.fire("x")
    arm("x", "disk_full")
    with pytest.raises(InjectedFault) as e:
        faults.fire("x")
    assert e.value.errno == 28                  # ENOSPC
    p = str(tmp_path / "run0.hpt")
    arm("x", "partial_write")
    with pytest.raises(InjectedFault):
        faults.fire("x", path=p)
    assert os.path.exists(p + ".tmp")           # torn half-write left behind
    with pytest.raises(ValueError, match="unknown fault kind"):
        arm("x", "meteor_strike")


def test_env_arming_and_spill_backcompat(monkeypatch):
    monkeypatch.setenv(faults.FAULTS_ENV, "scan.read:io_error:1")
    reset()
    with pytest.raises(InjectedFault):
        faults.fire("scan.read")
    faults.fire("scan.read")                    # one-shot under stable env
    monkeypatch.setenv(faults.FAULTS_ENV, "")
    monkeypatch.setenv(faults.SPILL_FAULT_ENV, "disk_full:1")
    reset()
    with pytest.raises(InjectedFault):          # legacy knob → spill.write
        faults.fire("spill.write")


def test_arm_schedule_is_seed_deterministic():
    sched1 = arm_schedule(11, ["scan.read", "spill.write"], n_faults=3)
    reset()
    sched2 = arm_schedule(11, ["scan.read", "spill.write"], n_faults=3)
    assert sched1 == sched2
    reset()
    assert arm_schedule(12, ["scan.read", "spill.write"],
                        n_faults=3) != sched1


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------
def test_policy_retries_transient_until_success():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    pol = FaultPolicy(max_retries=3)
    assert pol.run(flaky, site="t", sleep=lambda s: None) == "ok"
    assert calls["n"] == 3


def test_policy_fatal_fails_fast_no_retry():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise ValueError("deterministic bug")

    pol = FaultPolicy(max_retries=5)
    with pytest.raises(ValueError, match="deterministic bug"):
        pol.run(bad, site="t", sleep=lambda s: None)
    assert calls["n"] == 1                      # never retried


def test_policy_budget_exhaustion_is_itself_fatal():
    pol = FaultPolicy(max_retries=2)

    def always():
        raise OSError("down")

    with pytest.raises(RetryBudgetExceeded, match="all 3 attempts"):
        pol.run(always, site="t", sleep=lambda s: None)
    # nested policies must not multiply budgets: the outer loop sees a
    # fatal type and fails fast
    outer = FaultPolicy(max_retries=9)
    calls = {"n": 0}

    def inner():
        calls["n"] += 1
        return pol.run(always, site="t", sleep=lambda s: None)

    with pytest.raises(RetryBudgetExceeded):
        outer.run(inner, site="outer", sleep=lambda s: None)
    assert calls["n"] == 1


def test_policy_backoff_deterministic_and_capped():
    pol = FaultPolicy(backoff_base=0.01, backoff_factor=2.0,
                      backoff_max=0.05, jitter=0.1)
    d = [pol.delay(k, site="s") for k in range(8)]
    assert d == [pol.delay(k, site="s") for k in range(8)]  # reproducible
    assert all(x <= 0.05 * 1.1 + 1e-12 for x in d)          # capped
    assert d[1] > d[0]                                      # grows


# ---------------------------------------------------------------------------
# hardened IO: typed corruption + quarantine
# ---------------------------------------------------------------------------
def test_inconsistent_hpt_header_raises_typed_error(tmp_path):
    p = str(tmp_path / "bad.hpt")
    cols = {"x": np.arange(100, dtype=np.int32)}
    write_hpt(p, cols, 100)
    raw = bytearray(open(p, "rb").read())
    # header JSON is near the front; claim more rows than the buffer holds
    hdr_end = raw.index(b"}", raw.index(b"num_rows")) + 1
    txt = raw[:hdr_end + 200].decode("latin1")
    assert '"num_rows": 100' in txt
    patched = raw.replace(b'"num_rows": 100', b'"num_rows": 150', 1)
    open(p, "wb").write(patched)
    with pytest.raises(CorruptFragmentError) as e:
        read_hpt(p)
    msg = str(e.value)
    assert "bad.hpt" in msg and "150" in msg and "600" in msg \
        and "400" in msg  # file, claimed rows, expected + actual bytes
    assert isinstance(e.value, ValueError)      # fatal family: never retried


def test_truncated_hpt_still_integrity_error(tmp_path):
    p = str(tmp_path / "cut.hpt")
    write_hpt(p, {"x": np.arange(64, dtype=np.float32)}, 64)
    raw = open(p, "rb").read()
    open(p, "wb").write(raw[:-12])
    with pytest.raises(HptIntegrityError):
        read_hpt(p)


def test_scan_quarantine_skips_corrupt_run_with_sidecar(tmp_path):
    ctx = local_context()
    path = _dataset(tmp_path)
    frag = sorted(f for f in os.listdir(path) if f.endswith(".hpt"))[2]
    raw = open(os.path.join(path, frag), "rb").read()
    open(os.path.join(path, frag), "wb").write(raw[:-8])
    # default: typed raise naming the file
    with pytest.raises(CorruptFragmentError, match=frag.replace(".", r"\.")):
        LazyFrame.read_parquet(path, ctx).collect(strict=False)
    # quarantine: pipeline completes, rows from the bad run are dropped,
    # stats + sidecar record exactly what was lost
    rec = T.Collector("q")
    out = (LazyFrame.read_parquet(path, ctx, on_error="quarantine")
           .collect(strict=False, telemetry=rec))
    got = _rows(out)
    lost = np.arange(16, 24, dtype=np.float32)  # fragment 2 of 8-row groups
    assert not np.isin(lost, got["a"]).any()
    assert rec.metrics.counters["scan.fragments_quarantined"] == 1
    assert rec.metrics.counters["scan.rows_quarantined"] == 8
    side = json.load(open(os.path.join(path, "_hptmt_quarantine.json")))
    assert len(side["quarantined"]) == 1
    assert side["quarantined"][0]["rows"] == 8
    assert frag in side["quarantined"][0]["path"]
    with pytest.raises(ValueError, match="on_error"):
        LazyFrame.read_parquet(path, ctx, on_error="explode")


def test_scan_transient_fault_retried_by_policy(tmp_path):
    ctx = local_context()
    path = _dataset(tmp_path)
    arm("scan.read", "io_error", nth=1)
    rec = T.Collector("r")
    out = _pipeline(path, ctx).collect(
        strict=False, policy=FaultPolicy(max_retries=2, backoff_base=0.0),
        telemetry=rec)
    oracle = _pipeline(path, ctx).collect(strict=False)
    for k, v in _rows(oracle).items():
        np.testing.assert_array_equal(v, _rows(out)[k], err_msg=k)
    assert fires("scan.read") == 1
    assert rec.metrics.counters["fault.injected.scan.read"] == 1
    assert rec.metrics.counters["retry.scan.read"] == 1


# ---------------------------------------------------------------------------
# hardened checkpoint manager
# ---------------------------------------------------------------------------
def test_checkpoint_manifest_has_crc_and_restore_checks_it(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(8, dtype=jnp.float32), "b": jnp.ones((3,))}
    mgr.save(1, tree)
    man = json.load(open(tmp_path / "step_1" / "manifest.json"))
    assert all("crc32" in leaf for leaf in man["leaves"])
    ok = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(ok["w"]), np.arange(8))
    # flip one byte on disk → named integrity error on restore
    leaf = tmp_path / "step_1" / "w.npy"
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    with pytest.raises(CheckpointIntegrityError, match="CRC mismatch"):
        mgr.restore(jax.tree.map(jnp.zeros_like, tree))


def test_checkpoint_dtype_drift_refuses_silent_cast(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.arange(4, dtype=jnp.float32)})
    with pytest.raises(CheckpointIntegrityError, match="dtype"):
        mgr.restore({"w": jnp.zeros(4, dtype=jnp.int32)})
    assert issubclass(CheckpointIntegrityError, ValueError)


# ---------------------------------------------------------------------------
# workflow engine: policy routing + journal content hash
# ---------------------------------------------------------------------------
def test_workflow_routes_retries_through_policy(tmp_path):
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return 7

    wf = WorkflowEngine(policy=FaultPolicy(max_retries=3, backoff_base=0.0,
                                           backoff_max=0.0))
    wf.add(Task("t", flaky))
    assert wf.run()["t"] == 7 and calls["n"] == 3


def test_workflow_fatal_task_fails_fast():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise ValueError("bug")

    wf = WorkflowEngine().add(Task("t", bad, retries=5))
    with pytest.raises(WorkflowError, match="non-retryable ValueError"):
        wf.run()
    assert calls["n"] == 1


def test_workflow_journal_detects_stale_dag(tmp_path):
    j = str(tmp_path / "journal.json")
    wf = WorkflowEngine(j)
    wf.add(Task("a", lambda: 1)).add(Task("b", lambda a: a + 1, deps=("a",)))
    wf.run()
    entries = json.load(open(j))
    assert entries["a"]["hash"] and entries["b"]["hash"]
    # same DAG, fresh lambdas (a restart) → resumes silently
    wf2 = WorkflowEngine(j)
    wf2.add(Task("a", lambda: 99)).add(
        Task("b", lambda a: 0, deps=("a",)))
    assert wf2.run() == {}                      # everything skipped
    # changed dependency edges → stale journal must refuse, not skip
    wf3 = WorkflowEngine(j)
    wf3.add(Task("a", lambda: 1)).add(Task("b", lambda: 2))
    with pytest.raises(WorkflowError, match="stale journal"):
        wf3.run()


def test_workflow_legacy_bool_journal_still_resumes(tmp_path):
    j = str(tmp_path / "journal.json")
    with open(j, "w") as f:
        json.dump({"a": True}, f)
    wf = WorkflowEngine(j).add(Task("a", lambda: 1 / 0))
    assert wf.run() == {}                       # pre-hash entry skips


# ---------------------------------------------------------------------------
# lineage stage checkpoints
# ---------------------------------------------------------------------------
def test_plan_fingerprint_deterministic_and_sensitive(tmp_path):
    from repro.plan.rules import optimize
    ctx = local_context()
    path = _dataset(tmp_path)
    r1, _ = optimize(_pipeline(path, ctx).logical_plan)
    r2, _ = optimize(_pipeline(path, ctx).logical_plan)
    assert plan_fingerprint(r1, ctx) == plan_fingerprint(r2, ctx)
    other = (LazyFrame.read_parquet(path, ctx)
             .filter([pred("a", "<", 32.0)])     # different predicate
             .groupby(["b"], [("c", "sum"), ("c", "count")])
             .sort_values("b"))
    r3, _ = optimize(other.logical_plan)
    assert plan_fingerprint(r3, ctx) != plan_fingerprint(r1, ctx)


def test_stage_checkpointer_roundtrip_and_torn_commit_sweep(tmp_path):
    ctx = local_context()
    df = DataFrame.from_dict(
        {"k": np.arange(6, dtype=np.float32),
         "v": np.ones(6, dtype=np.float32)}, ctx)
    ck = StageCheckpointer(str(tmp_path), "fp0")
    ck.commit(2, df.table, [("plan.x", 3)], op="groupby")
    assert ck.committed_stages() == [2]
    dt, ovs = ck.restore(2)
    assert ovs == [("plan.x", 3)]
    for k in df.table.column_names:
        np.testing.assert_array_equal(np.asarray(df.table.columns[k]),
                                      np.asarray(dt.columns[k]))
    np.testing.assert_array_equal(np.asarray(df.table.counts),
                                  np.asarray(dt.counts))
    # a torn commit (crash before rename) is swept on reopen
    os.makedirs(tmp_path / "fp0" / "stage_5.tmp")
    ck2 = StageCheckpointer(str(tmp_path), "fp0")
    assert ck2.committed_stages() == [2]
    assert not os.path.exists(tmp_path / "fp0" / "stage_5.tmp")


def test_commit_crash_leaves_no_partial_stage(tmp_path):
    ctx = local_context()
    df = DataFrame.from_dict({"k": np.arange(4, dtype=np.float32)}, ctx)
    ck = StageCheckpointer(str(tmp_path), "fp1")
    arm("checkpoint.commit", "io_error", nth=1)
    with pytest.raises(InjectedFault):
        ck.commit(0, df.table, [])
    assert ck.committed_stages() == []          # nothing half-visible
    ck.commit(0, df.table, [])                  # disarmed retry succeeds
    assert ck.committed_stages() == [0]


def test_resilient_collect_bit_exact_and_resumes(tmp_path):
    ctx = local_context()
    path = _dataset(tmp_path)
    oracle = _rows(_pipeline(path, ctx).collect(strict=False))
    ckdir = str(tmp_path / "stages")
    pol = FaultPolicy(max_retries=1, checkpoint_dir=ckdir,
                      keep_checkpoints=True)
    rec = T.Collector("c1")
    got = _rows(_pipeline(path, ctx).collect(strict=False, policy=pol,
                                             telemetry=rec))
    for k, v in oracle.items():
        np.testing.assert_array_equal(v, got[k], err_msg=k)
    assert rec.metrics.counters["recovery.stages_committed"] >= 1
    [fp] = os.listdir(ckdir)                    # one fingerprint dir
    # second run resumes from the committed stage: restores, no re-commit
    rec2 = T.Collector("c2")
    got2 = _rows(_pipeline(path, ctx).collect(strict=False, policy=pol,
                                              telemetry=rec2))
    for k, v in oracle.items():
        np.testing.assert_array_equal(v, got2[k], err_msg=k)
    assert rec2.metrics.counters["recovery.stages_restored"] >= 1
    assert "recovery.resumed_from_stage" in rec2.metrics.gauges
    spans = [s.name for s in rec2.all_spans()]
    assert "recovery.restore" in spans and "recovery.collect" in spans


def test_collect_without_policy_is_zero_overhead(tmp_path):
    import tempfile
    ctx = local_context()
    path = _dataset(tmp_path)
    before = {d for d in os.listdir(tempfile.gettempdir())
              if d.startswith("hptmt-stages-")}
    lf = _pipeline(path, ctx)
    plan = lf.physical_plan()
    assert plan.stage_hook is None
    lf.collect(strict=False)
    after = {d for d in os.listdir(tempfile.gettempdir())
             if d.startswith("hptmt-stages-")}
    assert after == before                      # no stage IO, no tmp dirs
    assert fires() == 0


def test_successful_collect_removes_checkpoints_unless_kept(tmp_path):
    ctx = local_context()
    path = _dataset(tmp_path)
    ckdir = str(tmp_path / "stages")
    _pipeline(path, ctx).collect(
        strict=False, policy=FaultPolicy(checkpoint_dir=ckdir))
    assert os.listdir(ckdir) == []              # cleaned after success


# ---------------------------------------------------------------------------
# kill-and-resume: a real SIGKILL mid-commit, then bit-exact recovery
# ---------------------------------------------------------------------------
_CHILD = """
    import json, os, sys, zlib
    import numpy as np
    from repro import telemetry as T
    from repro.core import local_context
    from repro.io.dataset import write_dataset
    from repro.io.scan import pred
    from repro.plan.frame import LazyFrame
    from repro.resilience import FaultPolicy

    root, ckdir, mode = sys.argv[1], sys.argv[2], sys.argv[3]
    ds = os.path.join(root, "ds")
    if not os.path.exists(ds):
        rng = np.random.default_rng(5)
        n = 96
        cols = {"k": (np.arange(n) % 12).astype(np.float32),
                "u": np.arange(n, dtype=np.float32),
                "v": rng.normal(size=n).astype(np.float32)}
        write_dataset(ds, [(cols, n)], format="hpt", rows_per_group=12)
    ctx = local_context()
    lf = (LazyFrame.read_parquet(ds, ctx)
          .filter([pred("u", "<", 72.0)])
          .groupby(["k"], [("v", "sum"), ("v", "count")])
          .sort_values("v_sum"))  # non-key order → second exchange stage
    if mode == "plain":
        out = lf.collect(strict=False)
    else:
        rec = T.Collector("child")
        pol = FaultPolicy(max_retries=1, checkpoint_dir=ckdir,
                          keep_checkpoints=True)
        out = lf.collect(strict=False, policy=pol, telemetry=rec)
        print("RESTORED", rec.metrics.counters.get(
            "recovery.stages_restored", 0))
        print("RESUMED_FROM", rec.metrics.gauges.get(
            "recovery.resumed_from_stage", -1))
    d = out.to_numpy()
    crc = 0
    for k in sorted(d):
        crc = zlib.crc32(np.ascontiguousarray(d[k]).tobytes(), crc)
    print("CRC", f"{crc:08x}")
"""


def _run_child(tmp_path, mode, extra_env=None, check=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("HPTMT_FAULTS", None)
    env.update(extra_env or {})
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD),
         str(tmp_path), str(tmp_path / "stages"), mode],
        capture_output=True, text=True, timeout=560, env=env)
    if check:
        assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r


def test_sigkill_during_commit_then_resume_bit_exact(tmp_path):
    oracle = _run_child(tmp_path, "plain")
    ocrc = [l for l in oracle.stdout.splitlines() if l.startswith("CRC")]
    # run 1: SIGKILL the process during the FIRST stage commit — after
    # the tmp snapshot is written, before the atomic rename
    r1 = _run_child(tmp_path, "resilient",
                    {"HPTMT_FAULTS": "checkpoint.commit:crash:1"},
                    check=False)
    assert r1.returncode == -9, (r1.returncode, r1.stderr[-2000:])
    fpdirs = os.listdir(tmp_path / "stages")
    assert len(fpdirs) == 1                     # fingerprint dir exists
    # run 2: no faults — sweeps the torn commit, re-runs, commits
    r2 = _run_child(tmp_path, "resilient")
    assert ocrc[0] in r2.stdout                 # bit-exact vs oracle
    # run 3: resumes from the stage run 2 committed
    r3 = _run_child(tmp_path, "resilient")
    assert ocrc[0] in r3.stdout
    lines = dict(l.split() for l in r3.stdout.splitlines())
    assert int(lines["RESTORED"]) >= 1
    assert int(lines["RESUMED_FROM"]) >= 0


def test_crash_after_commit_resumes_without_recompute(tmp_path):
    # crash on the SECOND commit fire: stage 1 lands durably first
    r1 = _run_child(tmp_path, "resilient",
                    {"HPTMT_FAULTS": "checkpoint.commit:crash:2"},
                    check=False)
    if r1.returncode == 0:
        pytest.skip("pipeline has a single stage on this backend")
    assert r1.returncode == -9
    [fp] = os.listdir(tmp_path / "stages")
    committed = [d for d in os.listdir(tmp_path / "stages" / fp)
                 if d.startswith("stage_") and not d.endswith(".tmp")]
    assert committed                             # first stage survived
    oracle = _run_child(tmp_path, "plain")
    ocrc = [l for l in oracle.stdout.splitlines() if l.startswith("CRC")]
    r2 = _run_child(tmp_path, "resilient")
    assert ocrc[0] in r2.stdout
    assert "RESTORED 1" in r2.stdout or "RESTORED 2" in r2.stdout


# ---------------------------------------------------------------------------
# suffix-only re-execution: the jaxpr of a resumed plan must contain
# strictly fewer all_to_all ops (zero when every stage is committed)
# ---------------------------------------------------------------------------
def test_suffix_only_reexecution_4dev(tmp_path):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("HPTMT_FAULTS", None)
    script = """
        import os, sys
        import jax, numpy as np
        from repro.core import host_test_context
        from repro.dataframe.frame import DataFrame
        from repro.plan.frame import LazyFrame
        from repro.io.dataset import write_dataset
        from repro.io.scan import pred
        from repro.plan.rules import optimize
        from repro.plan.physical import PhysicalPlan
        from repro.resilience import (FaultPolicy, StageCheckpointer,
                                      plan_fingerprint, stage_hook)

        root = sys.argv[1]
        ds = os.path.join(root, "ds")
        rng = np.random.default_rng(7)
        n = 128
        cols = {"k": (np.arange(n) % 16).astype(np.float32),
                "u": np.arange(n, dtype=np.float32),
                "v": rng.normal(size=n).astype(np.float32)}
        write_dataset(ds, [(cols, n)], format="hpt", rows_per_group=16)
        ctx = host_test_context(n_shards=4)
        ckdir = os.path.join(root, "stages")

        def build():
            return (LazyFrame.read_parquet(ds, ctx)
                    .groupby(["k"], [("v", "sum")])
                    .sort_values("v_sum"))

        # full run with durable stages
        pol = FaultPolicy(checkpoint_dir=ckdir, keep_checkpoints=True)
        out1 = build().collect(strict=False, policy=pol)

        root_l, _ = optimize(build().logical_plan)
        fp = plan_fingerprint(root_l, ctx)
        ck = StageCheckpointer(ckdir, fp)
        committed = ck.committed_stages()
        assert committed, "no stages committed at 4 devices"

        fresh = PhysicalPlan(root_l, ctx)
        n_fresh = str(jax.make_jaxpr(fresh.fn)(*fresh.inputs())
                      ).count("all_to_all")
        assert n_fresh > 0, "pipeline has no exchanges at 4 devices"

        resumed = PhysicalPlan(root_l, ctx)
        resumed.stage_hook = stage_hook(ck, ctx=ctx,
                                        committed=set(committed))
        n_resumed = str(jax.make_jaxpr(resumed.fn)(*resumed.inputs())
                        ).count("all_to_all")
        # every exchange step is a stage; with all stages committed the
        # resumed program re-traces ONLY the post-exchange suffix
        assert n_resumed < n_fresh, (n_resumed, n_fresh)
        assert n_resumed == 0, (n_resumed, n_fresh)
        print("SUFFIX", n_fresh, "->", n_resumed)
    """
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script), str(tmp_path)],
        capture_output=True, text=True, timeout=560, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    assert "SUFFIX" in r.stdout
