"""Partitioning metadata, shuffle elision, and map-side combine (DESIGN.md §4).

Three layers of guarantees:

  * metadata propagation — which operators preserve, produce, or drop the
    ``(hash_keys, n_shards)`` layout record;
  * elision correctness — skipping the shuffle on pre-partitioned inputs
    yields bit-identical aggregates to the always-shuffle oracle, and the
    traced jaxpr really contains zero AllToAll;
  * map-side combine — pre-aggregated shuffles match the direct path for
    every aggregate, including the mean sum/count decomposition.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DistTable, Table, local_context, partitioning_kind,
                        range_partitioning, table_ops)
from repro.core.dataflow import TSet
from repro.dataframe.frame import DataFrame

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
RNG = np.random.default_rng(11)
CTX = local_context()


def make_dt(d):
    return DistTable.from_local(
        Table.from_arrays({k: jnp.asarray(v) for k, v in d.items()}), CTX)


# ---------------------------------------------------------------------------
# metadata propagation (single shard: pure bookkeeping)
# ---------------------------------------------------------------------------
def test_partitioning_lifecycle():
    dt = make_dt({"k": np.arange(8, dtype=np.int32),
                  "v": np.arange(8, dtype=np.float32)})
    assert dt.partitioning is None  # from_local proves nothing

    sh, _ = table_ops.shuffle(dt, ["k"], ctx=CTX)
    assert sh.partitioning == (("k",), 1)

    # select keeps rows on their shard -> preserved
    sel = table_ops.select(sh, lambda c: c["v"] >= 0, ctx=CTX)
    assert sel.partitioning == (("k",), 1)

    # project keeps the layout only while the hash keys survive
    assert table_ops.project(sh, ["k"], ctx=CTX).partitioning == (("k",), 1)
    assert table_ops.project(sh, ["v"], ctx=CTX).partitioning is None

    # orderby range-partitions: the hash layout is REPLACED by range
    # evidence (DESIGN.md §9), never silently dropped
    srt, _ = table_ops.orderby(sh, "v", ctx=CTX)
    assert srt.partitioning == range_partitioning(("v",), (True,), 1)
    assert partitioning_kind(srt.partitioning) == "range"
    # ...and hash-elision sites can never confuse it with hash evidence
    assert srt.partitioning != (("v",), 1)

    # keyed operators stamp their output
    g, _ = table_ops.groupby_aggregate(dt, ["k"], [("v", "sum")], ctx=CTX)
    assert g.partitioning == (("k",), 1)
    j, _ = table_ops.join(dt, dt, ["k"], ctx=CTX)
    assert j.partitioning == (("k",), 1)
    u, _ = table_ops.union(
        table_ops.project(dt, ["k"], ctx=CTX),
        table_ops.project(dt, ["k"], ctx=CTX), ctx=CTX)
    assert u.partitioning == (("k",), 1)

    # pytree round trip keeps the aux metadata
    leaves, treedef = jax.tree_util.tree_flatten(sh)
    assert jax.tree_util.tree_unflatten(
        treedef, leaves).partitioning == (("k",), 1)


def test_partitioning_exact_match_only():
    dt = make_dt({"a": np.arange(6, dtype=np.int32),
                  "b": np.arange(6, dtype=np.int32)})
    sh, _ = table_ops.shuffle(dt, ["a", "b"], ctx=CTX)
    # the murmur chain is order-sensitive: ("b","a") is a different layout
    assert sh.partitioning == (("a", "b"), 1)
    assert sh.partitioning != (("b", "a"), 1)


def test_tset_chunking_preserves_and_map_invalidates():
    dt = make_dt({"k": np.arange(16, dtype=np.int32),
                  "v": np.arange(16, dtype=np.float32)})
    sh, _ = table_ops.shuffle(dt, ["k"], ctx=CTX)
    chunks = TSet.from_table(sh, CTX, chunk_rows=4)
    for c in chunks._node.payload["chunks"]:
        assert c.partitioning == (("k",), 1)
    # a map over a non-key column keeps the layout; touching the key drops it
    kept = chunks.map_columns(lambda c: {"v": c["v"] * 2}).collect()
    assert kept.partitioning == (("k",), 1)
    dropped = chunks.map_columns(lambda c: {"k": c["k"] + 1}).collect()
    assert dropped.partitioning is None


def test_groupby_hash_method_matches_sort():
    n = 4096
    keys = RNG.integers(0, 37, n).astype(np.int32)
    keys2 = RNG.integers(0, 5, n).astype(np.int32)
    vals = RNG.normal(size=n).astype(np.float32)
    dt = make_dt({"k": keys, "k2": keys2, "v": vals})
    aggs = [("v", "sum"), ("v", "mean"), ("v", "min"), ("v", "max"),
            ("v", "count")]
    hs, ovh = table_ops.groupby_aggregate(dt, ["k", "k2"], aggs, ctx=CTX,
                                          out_capacity=512, method="hash")
    st, ovs = table_ops.groupby_aggregate(dt, ["k", "k2"], aggs, ctx=CTX,
                                          out_capacity=512, method="sort")
    assert int(ovh) == 0 and int(ovs) == 0
    a, b = hs.to_numpy(), st.to_numpy()
    oa = np.lexsort((a["k2"], a["k"]))
    ob = np.lexsort((b["k2"], b["k"]))
    np.testing.assert_array_equal(a["k"][oa], b["k"][ob])
    np.testing.assert_array_equal(a["k2"][oa], b["k2"][ob])
    for lbl in ("v_sum", "v_mean", "v_min", "v_max", "v_count"):
        np.testing.assert_allclose(a[lbl][oa], b[lbl][ob], rtol=1e-4,
                                   atol=1e-4, err_msg=lbl)


def test_groupby_out_capacity_above_input_capacity():
    # more output room than input rows: both kernels pad instead of crashing
    keys = RNG.integers(0, 40, 64).astype(np.int32)
    vals = RNG.normal(size=64).astype(np.float32)
    dt = make_dt({"k": keys, "v": vals})
    exp = {k: vals[keys == k].sum() for k in set(keys.tolist())}
    for method in ("sort", "hash"):
        out, ov = table_ops.groupby_aggregate(
            dt, ["k"], [("v", "sum")], ctx=CTX, out_capacity=130,
            method=method)
        got = out.to_numpy()
        assert int(ov) == 0 and len(got["k"]) == len(exp), method
        for k, s in zip(got["k"], got["v_sum"]):
            np.testing.assert_allclose(s, exp[int(k)], rtol=1e-4, atol=1e-4,
                                       err_msg=method)


def test_groupby_hash_nan_keys_do_not_corrupt():
    # NaN != NaN must not let NaN rows claim a fresh slot every probe
    # round and crowd out real groups: the hash kernel compares keys by
    # bit pattern, so equal-bit NaNs form ONE group and 1.0/2.0 survive
    keys = np.array([1.0, np.nan, 1.0, np.nan, 2.0], np.float32)
    vals = np.array([1.0, 10.0, 1.0, 10.0, 4.0], np.float32)
    dt = make_dt({"k": keys, "v": vals})
    out, ov = table_ops.groupby_aggregate(dt, ["k"], [("v", "sum")], ctx=CTX,
                                          out_capacity=8, method="hash")
    assert int(ov) == 0
    got = out.to_numpy()
    assert len(got["k"]) == 3
    by_key = {("nan" if np.isnan(k) else float(k)): s
              for k, s in zip(got["k"], got["v_sum"])}
    assert by_key[1.0] == 2.0
    assert by_key[2.0] == 4.0
    assert by_key["nan"] == 20.0


def test_groupby_hash_overflow_counted():
    # 64 distinct keys forced through an 8-group output: the surplus is
    # counted, never silently merged
    dt = make_dt({"k": np.arange(64, dtype=np.int32),
                  "v": np.ones(64, np.float32)})
    out, ov = table_ops.groupby_aggregate(dt, ["k"], [("v", "sum")], ctx=CTX,
                                          out_capacity=8, method="hash")
    assert int(out.counts.sum()) == 8
    assert int(ov) == 64 - 8


def test_from_dict_capacity_validation_and_headroom():
    data = {"k": np.arange(10, dtype=np.int32)}
    with pytest.raises(ValueError, match="cannot hold"):
        DataFrame.from_dict(data, CTX, capacity=4)
    df = DataFrame.from_dict(data, CTX, bucket_factor=2.0)
    assert df.table.capacity == 20  # headroom for later shuffle skew
    assert len(df) == 10
    assert df.partitioning is None
    assert df.repartition(["k"]).partitioning == (("k",), 1)


def test_groupby_trailing_dim_column_with_scalar_lanes():
    # a (n, 3) sum column fused alongside count/mean lanes: trailing dims
    # flatten to extra lanes and reshape back
    n = 256
    keys = RNG.integers(0, 9, n).astype(np.int32)
    emb = RNG.normal(size=(n, 3)).astype(np.float32)
    vals = RNG.normal(size=n).astype(np.float32)
    dt = make_dt({"k": keys, "e": emb, "v": vals})
    for method in ("sort", "hash"):
        out, ov = table_ops.groupby_aggregate(
            dt, ["k"], [("e", "sum"), ("v", "mean"), ("k", "count")],
            ctx=CTX, out_capacity=32, method=method)
        assert int(ov) == 0
        got = out.to_numpy()
        order = np.argsort(got["k"])
        for i, k in enumerate(got["k"][order]):
            sel = keys == k
            np.testing.assert_allclose(got["e_sum"][order][i],
                                       emb[sel].sum(axis=0), rtol=1e-4,
                                       atol=1e-4, err_msg=method)
            np.testing.assert_allclose(got["v_mean"][order][i],
                                       vals[sel].mean(), rtol=1e-4,
                                       atol=1e-4, err_msg=method)
            assert got["k_count"][order][i] == sel.sum()


def test_segment_reduce_fused_matches_per_column():
    from repro.kernels.segment_reduce import ops as segops

    n, s = 999, 64
    seg = jnp.asarray(RNG.integers(0, s + 2, n).astype(np.int32))  # + oob
    vals = jnp.asarray(RNG.normal(size=(n, 3)).astype(np.float32))
    fused = segops.segment_reduce_fused(vals, seg, s)
    for lane in range(3):
        exp = segops.segment_reduce(vals[:, lane], seg, s, op="sum")
        np.testing.assert_allclose(fused[:, lane], exp, rtol=1e-5,
                                   atol=1e-5)
    # Pallas interpret-mode kernel vs the jnp reference
    interp = segops.segment_reduce_fused(vals, seg, s, force="pallas")
    np.testing.assert_allclose(interp, fused, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# 4-shard: elision vs always-shuffle oracle + jaxpr AllToAll counts
# ---------------------------------------------------------------------------
def _run_devices(script: str, n: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_elision_and_combine_4way():
    out = _run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import (Table, DistTable, HPTMTContext, make_mesh,
                                local_context, table_ops)
        mesh = make_mesh((4,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        one = local_context()
        rng = np.random.default_rng(5)
        n = 256
        t = Table.from_arrays(
            {"id": jnp.asarray(rng.integers(0, 24, n).astype(np.int32)),
             "v": jnp.asarray(rng.normal(size=n).astype(np.float32))})
        dt = DistTable.from_local(t, ctx, capacity=128)
        aggs = [("v", "sum"), ("v", "mean"), ("v", "min"), ("v", "count")]
        ref, _ = table_ops.groupby_aggregate(
            DistTable.from_local(t, one), ["id"], aggs, ctx=one)
        rg = ref.to_numpy(); ro = np.argsort(rg["id"])

        def check(got, ov, what):
            assert int(ov) == 0, (what, int(ov))
            gg = got.to_numpy(); go = np.argsort(gg["id"])
            np.testing.assert_array_equal(gg["id"][go], rg["id"][ro], what)
            for lbl in ("v_sum", "v_mean", "v_min", "v_count"):
                np.testing.assert_allclose(gg[lbl][go], rg[lbl][ro],
                                           rtol=1e-4, atol=1e-4,
                                           err_msg=f"{what}:{lbl}")

        # map-side combine == direct shuffle == single-device oracle
        check(*table_ops.groupby_aggregate(dt, ["id"], aggs, ctx=ctx,
                                           combine=False), "direct")
        check(*table_ops.groupby_aggregate(dt, ["id"], aggs, ctx=ctx,
                                           combine=True), "combine")
        check(*table_ops.groupby_aggregate(dt, ["id"], aggs, ctx=ctx,
                                           combine=True, out_capacity=64),
              "combine-lowcard")

        # elision: pre-partitioned input, zero AllToAll, same numbers
        sh, ov = table_ops.shuffle(dt, ["id"], ctx=ctx)
        assert int(ov) == 0
        assert sh.partitioning == (("id",), 4)
        check(*table_ops.groupby_aggregate(sh, ["id"], aggs, ctx=ctx),
              "elided")
        jx = str(jax.make_jaxpr(lambda d: table_ops.groupby_aggregate(
            d, ["id"], aggs, ctx=ctx))(sh))
        assert jx.count("all_to_all") == 0, jx.count("all_to_all")

        # re-shuffle on the same keys is a traced no-op
        jx = str(jax.make_jaxpr(lambda d: table_ops.shuffle(
            d, ["id"], ctx=ctx))(sh))
        assert jx.count("all_to_all") == 0

        # groupby on OTHER keys must still shuffle (metadata mismatch)
        dt2 = DistTable.from_local(Table.from_arrays(
            {"id": t.columns["id"], "g": t.columns["id"] % 3,
             "v": t.columns["v"]}), ctx, capacity=128)
        sh2, _ = table_ops.shuffle(dt2, ["id"], ctx=ctx)
        jx = str(jax.make_jaxpr(lambda d: table_ops.groupby_aggregate(
            d, ["g"], [("v", "sum")], ctx=ctx))(sh2))
        assert jx.count("all_to_all") >= 1

        # set ops elide per side and stamp their output
        pa = table_ops.project(sh, ["id"], ctx=ctx)
        jx = str(jax.make_jaxpr(lambda x: table_ops.union(
            x, x, ctx=ctx))(pa))
        assert jx.count("all_to_all") == 0
        u, ov = table_ops.union(pa, pa, ctx=ctx)
        assert u.partitioning == (("id",), 4)
        got = sorted(u.to_numpy()["id"].tolist())
        assert got == sorted(set(np.asarray(t.columns["id"]).tolist()))
        print("ELISION-4WAY-OK")
        """)
    assert "ELISION-4WAY-OK" in out


def test_join_then_groupby_single_alltoall_4way():
    """The acceptance chain: join with a pre-partitioned left lowers to ONE
    AllToAll (right side only), and the following groupby on the join keys
    lowers to ZERO — verified on the traced jaxpr AND for values."""
    out = _run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import (Table, DistTable, HPTMTContext, make_mesh,
                                local_context, table_ops)
        mesh = make_mesh((4,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        one = local_context()
        rng = np.random.default_rng(9)
        lk = rng.permutation(96).astype(np.int32)
        rk = rng.permutation(96).astype(np.int32)[:64]
        lt = Table.from_arrays({"k": jnp.asarray(lk),
                                "a": jnp.asarray(lk, jnp.float32)})
        rt = Table.from_arrays({"k": jnp.asarray(rk),
                                "b": jnp.asarray(rk, jnp.float32)})
        l = DistTable.from_local(lt, ctx, capacity=48)
        r = DistTable.from_local(rt, ctx, capacity=32)
        lp, ov = table_ops.shuffle(l, ["k"], ctx=ctx)
        assert int(ov) == 0

        def chain(left, right):
            j, o1 = table_ops.join(left, right, ["k"], out_capacity=96,
                                   ctx=ctx)
            g, o2 = table_ops.groupby_aggregate(
                j, ["k"], [("a", "sum"), ("b", "mean")], ctx=ctx)
            return g, o1 + o2

        jx = str(jax.make_jaxpr(chain)(lp, r))
        assert jx.count("all_to_all") == 1, jx.count("all_to_all")

        # fully pre-partitioned chain: ZERO AllToAll
        rp, ov = table_ops.shuffle(r, ["k"], ctx=ctx)
        assert int(ov) == 0
        jx0 = str(jax.make_jaxpr(chain)(lp, rp))
        assert jx0.count("all_to_all") == 0, jx0.count("all_to_all")

        # and the values are the single-device truth either way
        g4, ov4 = chain(lp, r)
        g0, ov0 = chain(lp, rp)
        lo = DistTable.from_local(lt, one)
        roo = DistTable.from_local(rt, one)
        j1, _ = table_ops.join(lo, roo, ["k"], out_capacity=96, ctx=one)
        gr, _ = table_ops.groupby_aggregate(
            j1, ["k"], [("a", "sum"), ("b", "mean")], ctx=one)
        eg = gr.to_numpy(); eo = np.argsort(eg["k"])
        for got, ov in ((g4, ov4), (g0, ov0)):
            assert int(ov) == 0
            gg = got.to_numpy(); go = np.argsort(gg["k"])
            np.testing.assert_array_equal(gg["k"][go], eg["k"][eo])
            np.testing.assert_allclose(gg["a_sum"][go], eg["a_sum"][eo],
                                       rtol=1e-5)
            np.testing.assert_allclose(gg["b_mean"][go], eg["b_mean"][eo],
                                       rtol=1e-5)
        print("JOIN-GROUPBY-1A2A-OK")
        """)
    assert "JOIN-GROUPBY-1A2A-OK" in out


def test_dataflow_combiner_elides_merge_shuffle_4way():
    """The chunked combiner barrier: per-chunk partials are partitioned on
    the keys, so the merge groupby at the barrier issues no extra
    AllToAll beyond the per-chunk exchanges."""
    out = _run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import (Table, DistTable, HPTMTContext, make_mesh,
                                local_context, table_ops)
        from repro.core.dataflow import TSet
        mesh = make_mesh((4,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        one = local_context()
        rng = np.random.default_rng(2)
        n = 256
        t = Table.from_arrays(
            {"k": jnp.asarray(rng.integers(0, 13, n).astype(np.int32)),
             "v": jnp.asarray(rng.normal(size=n).astype(np.float32))})
        dt = DistTable.from_local(t, ctx, capacity=128)
        got = (TSet.from_table(dt, ctx, chunk_rows=32)
               .groupby(["k"], [("v", "sum"), ("v", "mean")]).collect())
        assert got.partitioning == (("k",), 4)
        ref, _ = table_ops.groupby_aggregate(
            DistTable.from_local(t, one), ["k"],
            [("v", "sum"), ("v", "mean")], ctx=one)
        a, b = got.to_numpy(), ref.to_numpy()
        oa, ob = np.argsort(a["k"]), np.argsort(b["k"])
        np.testing.assert_array_equal(a["k"][oa], b["k"][ob])
        np.testing.assert_allclose(a["v_sum"][oa], b["v_sum"][ob],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(a["v_mean"][oa], b["v_mean"][ob],
                                   rtol=1e-4, atol=1e-4)
        print("DATAFLOW-COMBINER-OK")
        """)
    assert "DATAFLOW-COMBINER-OK" in out
