"""Storage subsystem tests (repro.io, DESIGN.md §5).

Four layers of guarantees:

  * schema ↔ ColSpec mapping — the schema model computes the exact packed
    layout ``pack_columns`` produces, bidirectionally;
  * round-trip bit-exactness — native ``.hpt`` and Arrow paths preserve
    every packed dtype bit-for-bit, including ``-0.0``/``inf``/``nan``;
    nulls and ragged inputs are rejected eagerly with names;
  * pushdown — projection + predicate scans materialize only projected
    columns and skip prunable fragments (observable via reader stats),
    with results identical to a full scan + post-filter, and overflow
    obeying the §2 count-and-drop contract;
  * partitioned re-entry — a dataset written with ``partition_by`` scans
    back with ``DistTable.partitioning`` attached, so a join on the
    partition keys traces with zero left-side AllToAll (4-device
    subprocess, jaxpr-asserted).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import HAS_PYARROW, requires_pyarrow

import jax.numpy as jnp

from repro.core import local_context, table_ops
from repro.core.exchange import pack_columns, unpack_columns
from repro.dataframe.frame import DataFrame
from repro.io import (ColumnPredicate, Field, ScanSource, Schema,
                      open_dataset, pred, read_dataset, read_hpt,
                      write_dataset, write_hpt)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
RNG = np.random.default_rng(7)
CTX = local_context()

WEIRD_F32 = np.array([-0.0, 0.0, np.inf, -np.inf, np.nan, -np.nan,
                      np.float32(1e-40), 3.5], np.float32)

#: one column per packed dtype (§3.1), with adversarial payloads
ALL_DTYPE_COLS = {
    "f16": WEIRD_F32.astype(np.float16),
    "f32": WEIRD_F32,
    "f64": WEIRD_F32.astype(np.float64),
    "i8": np.array([-128, 127, 0, -1, 5, 6, 7, 8], np.int8),
    "i16": np.array([-32768, 32767, 0, -1, 5, 6, 7, 8], np.int16),
    "i32": np.array([-2**31, 2**31 - 1, 0, -1, 5, 6, 7, 8], np.int32),
    "i64": np.array([-2**63, 2**63 - 1, 0, -1, 5, 6, 7, 8], np.int64),
    "u8": np.array([0, 255, 1, 2, 3, 4, 5, 6], np.uint8),
    "u16": np.array([0, 65535, 1, 2, 3, 4, 5, 6], np.uint16),
    "u32": np.array([0, 2**32 - 1, 1, 2, 3, 4, 5, 6], np.uint32),
    "u64": np.array([0, 2**64 - 1, 1, 2, 3, 4, 5, 6], np.uint64),
    "b": np.array([1, 0, 1, 1, 0, 0, 1, 0], bool),
    "emb": np.arange(24, dtype=np.float32).reshape(8, 3) * -0.5,
}


def bit_equal(a: np.ndarray, b: np.ndarray, msg=""):
    """Bitwise equality — distinguishes -0.0 from 0.0 and NaN payloads."""
    assert a.dtype == b.dtype and a.shape == b.shape, \
        f"{msg}: {a.dtype}{a.shape} vs {b.dtype}{b.shape}"
    assert np.ascontiguousarray(a).tobytes() == \
        np.ascontiguousarray(b).tobytes(), msg


def make_events(n=1200, n_days=30, seed=3):
    rng = np.random.default_rng(seed)
    return {
        "user_id": rng.integers(0, 40, n).astype(np.int32),
        "day": np.sort(rng.integers(0, n_days, n)).astype(np.int32),
        "value": rng.normal(size=n).astype(np.float32),
        "score": rng.uniform(0, 1, n).astype(np.float32),
        "clicks": rng.integers(0, 9, n).astype(np.int32),
        "flag": rng.uniform(size=n) < 0.5,
    }


FORMATS = ["hpt"] + (["parquet"] if HAS_PYARROW else [])


# ===========================================================================
# schema ↔ ColSpec
# ===========================================================================
def test_schema_matches_packer_layout():
    # jax-resident columns (32-bit world): the schema's computed layout
    # must equal what pack_columns actually records
    cols = {"v": jnp.asarray(WEIRD_F32), "k": jnp.arange(8, dtype=jnp.int32),
            "b": jnp.asarray(ALL_DTYPE_COLS["b"]),
            "h": jnp.asarray(ALL_DTYPE_COLS["f16"]),
            "e": jnp.asarray(ALL_DTYPE_COLS["emb"])}
    buf, specs = pack_columns(cols)
    schema = Schema.from_columns(cols)
    assert schema.to_colspecs() == specs
    assert schema.row_width == buf.shape[1]
    # bidirectional: specs -> schema -> specs round trip
    assert Schema.from_colspecs(specs).to_colspecs() == specs
    # and unpack still inverts under the schema-derived specs
    back = unpack_columns(buf, schema.to_colspecs())
    for k in cols:
        bit_equal(np.asarray(back[k]), np.asarray(cols[k]), k)


def test_schema_lane_math_64bit_and_trailing():
    schema = Schema([Field("a", "int64"), Field("b", "float64", (3,)),
                     Field("c", "uint8", (2, 2)), Field("d", "bool")])
    by = {f.name: f for f in schema}
    assert by["a"].lanes == 2          # 8-byte -> 2 lanes
    assert by["b"].lanes == 6          # 3 elements x 2 lanes
    assert by["c"].lanes == 4          # 4 elements x 1 widened lane
    assert by["d"].lanes == 1
    assert schema.row_width == 13
    specs = schema.to_colspecs()
    assert [s.start for s in specs] == [0, 2, 8, 12]  # sorted-name order
    assert Schema.from_colspecs(specs) == schema


def test_schema_rejects_unsupported_dtype():
    with pytest.raises(TypeError, match="dictionary-encode"):
        Schema.from_columns({"s": np.array(["a", "b"])})


def test_schema_json_round_trip():
    schema = Schema.from_columns(ALL_DTYPE_COLS)
    assert Schema.from_json(schema.to_json()) == schema


# ===========================================================================
# round-trip bit-exactness
# ===========================================================================
def test_native_round_trip_bit_exact(tmp_path):
    path = str(tmp_path / "all.hpt")
    write_hpt(path, ALL_DTYPE_COLS)
    back, n = read_hpt(path)
    assert n == 8
    assert set(back) == set(ALL_DTYPE_COLS)
    for k, v in ALL_DTYPE_COLS.items():
        bit_equal(back[k], v, k)


def test_native_projection_reads_requested_only(tmp_path):
    path = str(tmp_path / "t.hpt")
    write_hpt(path, ALL_DTYPE_COLS)
    back, _ = read_hpt(path, columns=["f32", "emb"])
    assert set(back) == {"f32", "emb"}
    bit_equal(back["f32"], ALL_DTYPE_COLS["f32"])
    with pytest.raises(KeyError, match="nope"):
        read_hpt(path, columns=["nope"])


def test_native_ragged_rejected(tmp_path):
    with pytest.raises(ValueError, match="ragged"):
        write_hpt(str(tmp_path / "r.hpt"),
                  {"a": np.arange(3), "b": np.arange(4)})


@requires_pyarrow
def test_arrow_round_trip_bit_exact():
    from repro.io import from_arrow, to_arrow

    at = to_arrow(ALL_DTYPE_COLS)
    back, n = from_arrow(at)
    assert n == 8
    for k, v in ALL_DTYPE_COLS.items():
        bit_equal(back[k], v, k)


@requires_pyarrow
def test_arrow_schema_round_trip():
    schema = Schema.from_columns(ALL_DTYPE_COLS)
    assert Schema.from_arrow(schema.to_arrow()) == schema


@requires_pyarrow
def test_arrow_nulls_rejected_with_names():
    import pyarrow as pa

    from repro.io import from_arrow

    at = pa.table({"ok": pa.array([1, 2, 3], pa.int32()),
                   "holes": pa.array([1.0, None, 3.0], pa.float32())})
    with pytest.raises(ValueError, match="holes"):
        from_arrow(at)


@requires_pyarrow
def test_parquet_round_trip_bit_exact(tmp_path):
    from repro.io.parquet import read_row_groups, write_parquet

    path = str(tmp_path / "all.parquet")
    write_parquet(path, ALL_DTYPE_COLS)
    back, n = read_row_groups(path, [0])
    assert n == 8
    for k, v in ALL_DTYPE_COLS.items():
        bit_equal(back[k], v, k)


@requires_pyarrow
def test_dataframe_arrow_bridge():
    import pyarrow as pa

    df = DataFrame.from_dict({"k": np.arange(6, dtype=np.int32),
                              "v": WEIRD_F32[:6]}, CTX)
    at = df.to_arrow()
    assert isinstance(at, pa.Table)
    back = DataFrame.from_arrow(at, CTX)
    bit_equal(back.to_numpy()["v"], np.asarray(df.to_numpy()["v"]))


# ===========================================================================
# pushdown scans
# ===========================================================================
@pytest.mark.parametrize("fmt", FORMATS)
def test_pushdown_parity_and_stats(tmp_path, fmt):
    """Acceptance: scanning 2 of 6 columns with a selective predicate
    materializes only the projected columns, skips >=1 row group (reader
    stats), and matches the full scan + post-filter exactly."""
    cols = make_events()
    root = str(tmp_path / f"events_{fmt}")
    write_dataset(root, [(cols, 1200)], format=fmt, rows_per_group=150)

    src = ScanSource(root, ctx=CTX, columns=["user_id", "value"],
                     predicate=[pred("day", ">=", 5), pred("day", "<", 9)])
    dt, overflow = src.to_dist_table()
    st = src.stats
    assert overflow == 0
    assert st.columns_total == 6 and st.columns_read == 3  # proj + pred col
    assert st.row_groups_total == 8
    assert st.row_groups_skipped >= 1
    assert st.rows_scanned < st.rows_on_disk  # pruning really read less
    got = dt.to_numpy()
    assert set(got) == {"user_id", "value"}  # pred col not materialized out

    full, ov_full, st_full = read_dataset(root, ctx=CTX)
    assert ov_full == 0 and st_full.row_groups_skipped == 0
    fn = full.to_numpy()
    mask = (fn["day"] >= 5) & (fn["day"] < 9)
    # row order is preserved by the scan, so parity is positional
    bit_equal(got["user_id"], fn["user_id"][mask])
    bit_equal(got["value"], fn["value"][mask])
    assert st.rows_selected == int(mask.sum())


@pytest.mark.parametrize("fmt", FORMATS)
def test_pushdown_operator_coverage(tmp_path, fmt):
    """Every predicate op against the full-scan oracle."""
    cols = make_events(n=600)
    root = str(tmp_path / f"ev_{fmt}")
    write_dataset(root, [(cols, 600)], format=fmt, rows_per_group=100)
    full = read_dataset(root, ctx=CTX)[0].to_numpy()
    ops = {"<": np.less, "<=": np.less_equal, ">": np.greater,
           ">=": np.greater_equal, "==": np.equal, "!=": np.not_equal}
    for op, npop in ops.items():
        dt, ov, _ = read_dataset(root, ctx=CTX, predicate=pred("day", op, 7))
        assert ov == 0
        bit_equal(dt.to_numpy()["value"],
                  full["value"][npop(full["day"], 7)], op)


def test_predicate_validation(tmp_path):
    root = str(tmp_path / "v")
    write_dataset(root, [(ALL_DTYPE_COLS, 8)], format="hpt")
    with pytest.raises(KeyError, match="missing"):
        ScanSource(root, ctx=CTX, predicate=pred("missing", "<", 1))
    with pytest.raises(ValueError, match="trailing"):
        ScanSource(root, ctx=CTX, predicate=pred("emb", "<", 1))
    with pytest.raises(ValueError, match="unknown predicate op"):
        ColumnPredicate("f32", "~", 1)


def test_nan_stats_never_prune(tmp_path):
    # NaNs poison min/max: the fragment must stay scannable, and the
    # residual filter gives the exact (NaN-excluding) comparison result
    root = str(tmp_path / "nan")
    write_dataset(root, [({"x": WEIRD_F32,
                           "i": np.arange(8, dtype=np.int32)}, 8)],
                  format="hpt")
    ds = open_dataset(root)
    assert ds.fragments[0].stats["x"] is None
    assert ds.fragments[0].stats["i"] == (0, 7)
    dt, ov, st = read_dataset(root, ctx=CTX, predicate=pred("x", ">", 0))
    assert st.row_groups_skipped == 0
    got = dt.to_numpy()
    assert got["i"].tolist() == [2, 6, 7]  # inf, 1e-40 and 3.5


@pytest.mark.parametrize("fmt", FORMATS)
def test_float_ne_predicate_never_prunes(tmp_path, fmt):
    # Parquet computes min/max ignoring NaNs, so min==max==v does NOT
    # prove all rows equal v — "!=" on float columns must skip pruning
    # and let the residual filter keep the NaN rows
    root = str(tmp_path / f"ne_{fmt}")
    x = np.array([1.0, 1.0, np.nan, 1.0], np.float32)
    write_dataset(root, [({"x": x, "i": np.arange(4, dtype=np.int32)}, 4)],
                  format=fmt)
    dt, ov, st = read_dataset(root, ctx=CTX, predicate=pred("x", "!=", 1.0))
    assert ov == 0 and st.row_groups_skipped == 0
    got = dt.to_numpy()
    assert got["i"].tolist() == [2]  # exactly the NaN row survives
    # int columns still prune on "!=" when stats prove uniformity
    root2 = str(tmp_path / f"ne_int_{fmt}")
    write_dataset(root2, [({"k": np.full(4, 7, np.int32),
                            "i": np.arange(4, dtype=np.int32)}, 4)],
                  format=fmt)
    _, _, st2 = read_dataset(root2, ctx=CTX, predicate=pred("k", "!=", 7))
    assert st2.row_groups_skipped == 1


def test_scan_stats_reset_per_materialization(tmp_path):
    cols = make_events(n=300)
    root = str(tmp_path / "stats")
    write_dataset(root, [(cols, 300)], format="hpt", rows_per_group=60)
    src = ScanSource(root, ctx=CTX)
    src.to_dist_table()
    first = src.stats.rows_scanned
    src.to_dist_table()  # a second run must not double-count
    assert src.stats.rows_scanned == first == 300
    list(src.chunks())
    assert src.stats.rows_scanned == 300


@pytest.mark.parametrize("fmt", FORMATS)
def test_scan_overflow_count_and_drop(tmp_path, fmt):
    """§2 contract: rows beyond an explicit capacity are counted and
    dropped in original row order — never silently corrupted."""
    cols = make_events(n=500)
    root = str(tmp_path / f"ovf_{fmt}")
    write_dataset(root, [(cols, 500)], format=fmt, rows_per_group=100)
    dt, overflow, st = read_dataset(root, ctx=CTX, capacity=120)
    assert overflow == 500 - 120
    assert st.rows_overflowed == 380
    assert int(dt.num_rows()) == 120
    # deterministic prefix in original row order
    bit_equal(dt.to_numpy()["value"], cols["value"][:120])


def test_scan_plans_capacity_from_metadata(tmp_path):
    cols = make_events(n=321)
    root = str(tmp_path / "cap")
    write_dataset(root, [(cols, 321)], format="hpt", rows_per_group=64)
    src = ScanSource(root, ctx=CTX)
    assert src.shard_capacity == 321  # exact plan, no load needed
    dt, ov = src.to_dist_table()
    assert ov == 0 and int(dt.num_rows()) == 321


def test_scan_bucket_factor_headroom(tmp_path):
    # mirrors DataFrame.from_dict: head-room so a later shuffle's hash
    # skew does not overflow a 100%-occupancy scanned table
    cols = make_events(n=200)
    root = str(tmp_path / "bf")
    write_dataset(root, [(cols, 200)], format="hpt")
    assert ScanSource(root, ctx=CTX).shard_capacity == 200
    src = ScanSource(root, ctx=CTX, bucket_factor=1.5)
    assert src.shard_capacity == 300
    dt, ov = src.to_dist_table()
    assert ov == 0 and int(dt.num_rows()) == 200 and dt.capacity == 300


def test_scan_64bit_narrowing_guard(tmp_path):
    import jax

    if jax.config.jax_enable_x64:
        pytest.skip("x64 enabled: no narrowing to guard")
    root = str(tmp_path / "wide")
    write_dataset(root, [({"big": np.array([1, 2**40], np.int64),
                           "ok64": np.array([1, 2], np.int64)}, 2)],
                  format="hpt")
    with pytest.raises(ValueError, match="big"):
        read_dataset(root, ctx=CTX)
    dt, _, _ = read_dataset(root, ctx=CTX, columns=["ok64"])  # values fit
    assert dt.to_numpy()["ok64"].tolist() == [1, 2]
    dt, _, _ = read_dataset(root, ctx=CTX, allow_narrowing=True)
    assert dt.to_numpy()["ok64"].tolist() == [1, 2]


@pytest.mark.parametrize("fmt", FORMATS)
def test_scan_chunks_to_tset_out_of_core(tmp_path, fmt):
    """Fragment-round chunk stream through the dataflow combiner matches
    the eager whole-table groupby."""
    from repro.core.dataflow import TSet

    cols = make_events(n=800)
    root = str(tmp_path / f"tset_{fmt}")
    write_dataset(root, [(cols, 800)], format=fmt, rows_per_group=128)
    src = ScanSource(root, ctx=CTX, columns=["user_id", "value"])
    chunks = list(src.chunks())  # lazy generator: one round per next()
    assert len(chunks) == 7  # ceil(800/128) fragment rounds
    got = (TSet.from_scan(ScanSource(root, ctx=CTX,
                                     columns=["user_id", "value"]))
           .groupby(["user_id"], [("value", "sum"), ("value", "count")])
           .collect())
    eager, _ = table_ops.groupby_aggregate(
        read_dataset(root, ctx=CTX)[0], ["user_id"],
        [("value", "sum"), ("value", "count")], ctx=CTX)
    a, b = got.to_numpy(), eager.to_numpy()
    oa, ob = np.argsort(a["user_id"]), np.argsort(b["user_id"])
    np.testing.assert_array_equal(a["user_id"][oa], b["user_id"][ob])
    np.testing.assert_allclose(a["value_sum"][oa], b["value_sum"][ob],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(a["value_count"][oa],
                                  b["value_count"][ob])


# ===========================================================================
# partitioning manifest & re-entry
# ===========================================================================
@pytest.mark.parametrize("fmt", FORMATS)
def test_partitioned_write_read_reattaches_metadata(tmp_path, fmt):
    df = DataFrame.from_dict(make_events(n=400), CTX)
    root = str(tmp_path / f"part_{fmt}")
    df.to_parquet(root, partition_by=["user_id"], format=fmt)
    assert open_dataset(root).partitioning == (("user_id",), 1)

    back = DataFrame.read_parquet(root, CTX)
    assert back.partitioning == (("user_id",), 1)
    # dropping a key column in the projection drops the evidence
    proj = DataFrame.read_parquet(root, CTX, columns=["day", "value"])
    assert proj.partitioning is None
    # a predicate is a select: rows never change shards, evidence survives
    filt = DataFrame.read_parquet(root, CTX, predicate=pred("day", "<", 9))
    assert filt.partitioning == (("user_id",), 1)


def test_unpartitioned_dataset_has_no_evidence(tmp_path):
    df = DataFrame.from_dict(make_events(n=100), CTX)
    root = str(tmp_path / "plain")
    df.to_parquet(root, format="hpt")
    assert open_dataset(root).partitioning is None
    assert DataFrame.read_parquet(root, CTX).partitioning is None


def test_roundtrip_values_through_partitioned_dataset(tmp_path):
    cols = make_events(n=300)
    df = DataFrame.from_dict(cols, CTX)
    root = str(tmp_path / "pv")
    df.to_parquet(root, partition_by=["user_id"], format="hpt")
    back = DataFrame.read_parquet(root, CTX).to_numpy()
    # single shard: the shuffle is an intra-shard permutation; compare as
    # multisets keyed by (user_id, value) bits
    order = np.lexsort((cols["value"].view(np.uint32), cols["user_id"]))
    border = np.lexsort((back["value"].view(np.uint32), back["user_id"]))
    for k in cols:
        bit_equal(back[k][border], cols[k][order], k)


# ===========================================================================
# 4-device mesh: zero left-side AllToAll on partitioned read → join
# ===========================================================================
def _run_devices(script: str, n: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_partitioned_read_join_elision_4way(tmp_path):
    """Acceptance: read_parquet of a hash-partitioned dataset -> join on
    the partition keys traces with zero left-side all_to_all equations
    (1 total for the unpartitioned right, 0 when both sides re-enter)."""
    fmt = "parquet" if HAS_PYARROW else "hpt"
    out = _run_devices(f"""
        import os, numpy as np, jax, jax.numpy as jnp
        from repro.core import HPTMTContext, make_mesh, table_ops, local_context
        from repro.dataframe.frame import DataFrame
        fmt = {fmt!r}
        root = {str(tmp_path)!r}
        mesh = make_mesh((4,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        rng = np.random.default_rng(9)
        n = 96
        lk = rng.permutation(n).astype(np.int32)
        rk = rng.permutation(n).astype(np.int32)[:64]
        left = DataFrame.from_dict(
            {{"k": lk, "a": lk.astype(np.float32)}}, ctx, bucket_factor=2.0)
        right = DataFrame.from_dict(
            {{"k": rk, "b": rk.astype(np.float32)}}, ctx, bucket_factor=2.0)
        lroot = os.path.join(root, "left_ds")
        left.to_parquet(lroot, partition_by=["k"], format=fmt)
        lp = DataFrame.read_parquet(lroot, ctx)
        assert lp.partitioning == (("k",), 4), lp.partitioning

        def chain(l, r):
            return table_ops.join(l, r, ["k"], out_capacity=48, ctx=ctx)

        jx = str(jax.make_jaxpr(chain)(lp.table, right.table))
        assert jx.count("all_to_all") == 1, jx.count("all_to_all")

        rroot = os.path.join(root, "right_ds")
        right.to_parquet(rroot, partition_by=["k"], format=fmt)
        rp = DataFrame.read_parquet(rroot, ctx)
        jx0 = str(jax.make_jaxpr(chain)(lp.table, rp.table))
        assert jx0.count("all_to_all") == 0, jx0.count("all_to_all")

        # values match the single-device truth
        one = local_context()
        exp = (DataFrame.from_dict({{"k": lk, "a": lk.astype(np.float32)}}, one)
               .join(DataFrame.from_dict(
                   {{"k": rk, "b": rk.astype(np.float32)}}, one),
                   on=["k"], out_capacity=96).to_numpy())
        got = lp.join(rp, on=["k"], out_capacity=48).to_numpy()
        eo, go = np.argsort(exp["k"]), np.argsort(got["k"])
        np.testing.assert_array_equal(got["k"][go], exp["k"][eo])
        np.testing.assert_allclose(got["b"][go], exp["b"][eo])
        np.testing.assert_allclose(got["a"][go], exp["a"][eo])

        # mismatched shard count: evidence must NOT attach on a 2-shard read
        mesh2 = make_mesh((2,), ("data",), devices=jax.devices()[:2])
        ctx2 = HPTMTContext(mesh=mesh2)
        lp2 = DataFrame.read_parquet(lroot, ctx2)
        assert lp2.partitioning is None, lp2.partitioning
        assert int(lp2.table.num_rows()) == n
        print("IO-ELISION-4WAY-OK")
        """)
    assert "IO-ELISION-4WAY-OK" in out


# ===========================================================================
# satellites: from_dict validation, pyarrow-absent leg
# ===========================================================================
def test_from_dict_ragged_names_offenders():
    with pytest.raises(ValueError) as ei:
        DataFrame.from_dict({"a": np.arange(4), "b": np.arange(4),
                             "short": np.arange(2)}, CTX)
    assert "short has 2 rows" in str(ei.value)
    assert "4 rows" in str(ei.value)


def test_pyarrow_absent_leg_native_works(tmp_path):
    """With pyarrow force-disabled, auto-format falls back to .hpt, scans
    work, and parquet asks fail with an actionable error."""
    script = textwrap.dedent(f"""
        import os
        os.environ["HPTMT_DISABLE_PYARROW"] = "1"
        import numpy as np
        from repro.core import local_context
        from repro.dataframe.frame import DataFrame
        from repro.io import has_pyarrow, pred
        assert not has_pyarrow()
        ctx = local_context()
        df = DataFrame.from_dict(
            {{"k": np.arange(50, dtype=np.int32),
              "v": np.arange(50, dtype=np.float32)}}, ctx)
        root = os.path.join({str(tmp_path)!r}, "ds")
        df.to_parquet(root, format=None, rows_per_group=10,
                      partition_by=["k"])
        back = DataFrame.read_parquet(root, ctx, predicate=pred("k", "<", 20))
        assert len(back) == 20
        assert back.partitioning == (("k",), 1)
        try:
            df.to_parquet(os.path.join({str(tmp_path)!r}, "pq"),
                          format="parquet")
        except RuntimeError as e:
            assert "pyarrow" in str(e)
        else:
            raise AssertionError("parquet write should have raised")
        print("ABSENT-LEG-OK")
        """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "ABSENT-LEG-OK" in r.stdout


def test_disk_corpus_matches_synthetic(tmp_path):
    """The training-data ingest path: corpus written to disk and scanned
    back yields the same curated token stream as the in-memory corpus."""
    sys.path.insert(0, os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "scripts")))
    from make_dataset import make_corpus_dataset

    from repro.data.pipeline import (CorpusConfig, disk_corpus, preprocess,
                                     synthetic_corpus)

    ccfg = CorpusConfig(n_docs=16, mean_doc_len=24, vocab_size=64, seed=4)
    root = str(tmp_path / "corpus")
    make_corpus_dataset(root, n_docs=16, mean_doc_len=24, vocab_size=64,
                        fmt="hpt", seed=4)
    mem = preprocess(synthetic_corpus(ccfg, CTX), ccfg, CTX)
    disk = preprocess(disk_corpus(root, CTX), ccfg, CTX)
    np.testing.assert_array_equal(mem, disk)
