"""Parity tests for the packed single-collective exchange engine.

The new engine (``core/exchange.py``) must produce row-for-row identical
tables — columns, counts, overflow — to the seed per-column argsort path
(kept as ``exchange_rows_reference``) across dtypes, shard counts, and
overflow-triggering capacities; plus the fused Pallas ``hash_partition``
kernel (interpret mode) must match the jnp oracle bit-for-bit.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DistTable, Table, local_context, table_ops
from repro.core import exchange as ex
from repro.core.table import hash_columns

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
RNG = np.random.default_rng(7)
CTX = local_context()


def _mixed_cols(n, rng=RNG):
    return {
        "i": jnp.asarray(rng.integers(-1000, 1000, n).astype(np.int32)),
        "u": jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint64
                                      ).astype(np.uint32)),
        "f": jnp.asarray(rng.normal(size=n).astype(np.float32)),
        "b": jnp.asarray(rng.random(n) < 0.5),
        "m": jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
    }


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------
def test_pack_unpack_roundtrip_bit_exact():
    cols = _mixed_cols(97)
    # adversarial float bit patterns must survive the round trip
    cols["f"] = cols["f"].at[0].set(-0.0).at[1].set(jnp.inf).at[2].set(
        jnp.nan)
    buf, specs = ex.pack_columns(cols)
    assert buf.dtype == jnp.uint32
    assert buf.shape == (97, 1 + 1 + 1 + 1 + 3)
    back = ex.unpack_columns(buf, specs)
    assert set(back) == set(cols)
    for k in cols:
        assert back[k].dtype == cols[k].dtype, k
        np.testing.assert_array_equal(
            np.asarray(back[k]).view(np.uint8).reshape(-1),
            np.asarray(cols[k]).view(np.uint8).reshape(-1), err_msg=k)


def test_dest_ranks_matches_argsort_rank():
    n, p = 513, 7
    dest = jnp.asarray(RNG.integers(0, p + 1, n).astype(np.int32))
    got = np.asarray(ex.dest_ranks(dest, p))
    # oracle: stable-argsort-based rank (the seed algorithm)
    order = np.argsort(np.asarray(dest), kind="stable")
    sdest = np.asarray(dest)[order]
    first = np.searchsorted(sdest, sdest, side="left")
    rank_sorted = np.arange(n) - first
    rank = np.empty(n, np.int64)
    rank[order] = rank_sorted
    valid = np.asarray(dest) < p
    np.testing.assert_array_equal(got[valid], rank[valid])


def test_compact_rows_matches_argsort_compaction():
    n = 200
    cols = _mixed_cols(n)
    keep = jnp.asarray(RNG.random(n) < 0.6)
    for out_cap in (n, 50):  # 50 triggers truncation overflow
        got, cnt, trunc = ex.compact_rows(cols, keep, out_cap)
        order = np.argsort(~np.asarray(keep), kind="stable")
        total = int(np.asarray(keep).sum())
        exp_cnt = min(total, out_cap)
        assert int(cnt) == exp_cnt
        assert int(trunc) == total - exp_cnt
        for k in cols:
            exp = np.asarray(cols[k])[order][:out_cap][:exp_cnt]
            np.testing.assert_array_equal(
                np.asarray(got[k])[:exp_cnt], exp, err_msg=k)


# ---------------------------------------------------------------------------
# packed exchange vs seed per-column reference (local, n_shards simulated)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_shards,bucket", [(1, 64), (4, 16), (4, 5)])
def test_exchange_parity_vs_reference(n_shards, bucket):
    """bucket=5 forces send-side overflow; valid rows must still agree."""
    n = 64
    cols = _mixed_cols(n)
    dest = jnp.asarray(RNG.integers(0, n_shards + 1, n).astype(np.int32))
    got, gvalid, gov = ex.exchange_rows(cols, dest, n_shards, bucket, None)
    exp, evalid, eov = ex.exchange_rows_reference(cols, dest, n_shards,
                                                  bucket, None)
    assert int(gov) == int(eov)
    np.testing.assert_array_equal(np.asarray(gvalid), np.asarray(evalid))
    v = np.asarray(evalid)
    for k in cols:
        np.testing.assert_array_equal(np.asarray(got[k])[v],
                                      np.asarray(exp[k])[v], err_msg=k)


@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.float32])
def test_shuffle_parity_single_shard_dtypes(dtype):
    n = 50
    vals = RNG.integers(0, 100, n).astype(dtype)
    dt = DistTable.from_local(
        Table.from_arrays({"x": jnp.asarray(vals)}), CTX)
    out, ov = table_ops.shuffle(dt, ["x"], ctx=CTX)
    assert int(ov) == 0
    got = out.to_numpy()["x"]
    assert got.dtype == dtype
    np.testing.assert_array_equal(np.sort(got), np.sort(vals))


def test_reserved_hash_column_names_rejected():
    l = DistTable.from_local(Table.from_arrays(
        {"k": jnp.arange(4, dtype=jnp.int32),
         "_h1": jnp.arange(4, dtype=jnp.uint32)}), CTX)
    r = DistTable.from_local(Table.from_arrays(
        {"k": jnp.arange(4, dtype=jnp.int32),
         "b": jnp.arange(4, dtype=jnp.float32)}), CTX)
    with pytest.raises(ValueError, match="reserved"):
        table_ops.join(l, r, ["k"], ctx=CTX)
    bad = DistTable.from_local(Table.from_arrays(
        {"_h1": jnp.arange(4, dtype=jnp.uint32),
         "_h2": jnp.arange(4, dtype=jnp.uint32)}), CTX)
    with pytest.raises(ValueError, match="reserved"):
        table_ops.union(bad, bad, ctx=CTX)


def test_dest_ranks_chunked_many_partitions():
    # more partitions than the chunk size exercises the chunk loop
    n, p = 257, 50
    dest = jnp.asarray(RNG.integers(0, p + 1, n).astype(np.int32))
    got = np.asarray(ex.dest_ranks(dest, p, chunk=16))
    d = np.asarray(dest)
    exp = np.array([int((d[:i] == d[i]).sum()) for i in range(n)])
    valid = d < p
    np.testing.assert_array_equal(got[valid], exp[valid])


def test_shuffle_overflow_counted_not_corrupted():
    n = 40
    dt = DistTable.from_local(Table.from_arrays(
        {"x": jnp.arange(n, dtype=jnp.int32)}), CTX)
    out, ov = table_ops.shuffle(dt, ["x"], out_capacity=25, ctx=CTX)
    assert int(ov) == n - 25
    got = out.to_numpy()["x"]
    assert len(got) == 25
    assert len(set(got.tolist())) == 25  # no duplicated/corrupted rows


# ---------------------------------------------------------------------------
# fused hash_partition kernel: hashes out of the Pallas path
# ---------------------------------------------------------------------------
def test_hash_partition_return_hashes_bit_equal():
    from repro.core.table import _as_u32
    from repro.kernels.hash_partition import kernel as hk, ref as hr

    n, p = 300, 8
    cols = [jnp.asarray(RNG.integers(0, 1000, n), jnp.int32),
            jnp.asarray(RNG.normal(size=n), jnp.float32)]
    valid = jnp.asarray(RNG.random(n) < 0.8)
    keys = jnp.stack([_as_u32(c) for c in cols], axis=1)
    dg, hg, h1g, h2g = hk.hash_partition_pallas(
        keys, valid, p, interpret=True, block_n=128, return_hashes=True)
    de, he, h1e, h2e = hr.hash_partition_full(cols, p, valid)
    np.testing.assert_array_equal(dg, de)
    np.testing.assert_array_equal(hg, he)
    np.testing.assert_array_equal(h1g, h1e)
    np.testing.assert_array_equal(h2g, h2e)
    # and against the user-facing hash
    h1, h2 = hash_columns(cols)
    np.testing.assert_array_equal(h1g, h1)
    np.testing.assert_array_equal(h2g, h2)


def test_hash_partition_ops_dispatcher_force_pallas():
    from repro.kernels.hash_partition import ops as hpops

    n, p = 100, 4
    col = jnp.asarray(RNG.integers(0, 50, n), jnp.int32)
    valid = jnp.ones((n,), bool)
    d1, h1 = hpops.hash_partition([col], p, valid)
    d2, h2, a, b = hpops.hash_partition([col], p, valid, force="pallas",
                                        return_hashes=True)
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(h1, h2)
    e1, e2 = hash_columns([col])
    np.testing.assert_array_equal(a, e1)
    np.testing.assert_array_equal(b, e2)


# ---------------------------------------------------------------------------
# multi-shard: operator-level parity vs single-device + collective count
# ---------------------------------------------------------------------------
def _run_devices(script: str, n: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_exchange_4way_parity_and_single_collective():
    out = _run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import (Table, DistTable, HPTMTContext, make_mesh,
                                local_context, table_ops)
        mesh = make_mesh((4,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        one = local_context()
        rng = np.random.default_rng(3)
        n = 128
        cols = {"id": jnp.asarray(rng.integers(0, 40, n).astype(np.int32)),
                "v": jnp.asarray(rng.normal(size=n).astype(np.float32)),
                "w": jnp.asarray(rng.integers(0, 2**31, n).astype(np.uint32))}
        t = Table.from_arrays(cols)

        # shuffle: same row multiset as the single-device identity, 0 overflow
        # (capacity 2x the per-shard row count absorbs hash skew)
        dt = DistTable.from_local(t, ctx, capacity=64)
        sh, ov = table_ops.shuffle(dt, ["id"], ctx=ctx)
        assert int(ov) == 0 and int(sh.num_rows()) == n
        got = sh.to_numpy()
        rows = sorted(zip(got["id"].tolist(), got["w"].tolist(),
                          got["v"].tolist()))
        exp = sorted(zip(np.asarray(cols["id"]).tolist(),
                         np.asarray(cols["w"]).tolist(),
                         np.asarray(cols["v"]).tolist()))
        assert rows == exp, "shuffled row multiset differs"

        # groupby on 4 shards == groupby on 1 device
        g4, _ = table_ops.groupby_aggregate(dt, ["id"], [("v", "sum")],
                                            ctx=ctx)
        g1, _ = table_ops.groupby_aggregate(
            DistTable.from_local(t, one), ["id"], [("v", "sum")], ctx=one)
        a, b = g4.to_numpy(), g1.to_numpy()
        oa, ob = np.argsort(a["id"]), np.argsort(b["id"])
        np.testing.assert_array_equal(a["id"][oa], b["id"][ob])
        np.testing.assert_allclose(a["v_sum"][oa], b["v_sum"][ob],
                                   rtol=1e-5)

        # overflow-triggering bucket: counted, survivors intact
        tiny, ov = table_ops.shuffle(dt, ["id"], bucket_factor=0.25,
                                     ctx=ctx)
        assert int(ov) > 0
        assert int(tiny.num_rows()) + int(ov) == n

        # the traced shuffle contains exactly ONE all_to_all, zero sorts
        jaxpr = str(jax.make_jaxpr(
            lambda d: table_ops.shuffle(d, ["id"], ctx=ctx))(dt))
        assert jaxpr.count("all_to_all") == 1, jaxpr.count("all_to_all")
        assert jaxpr.count("sort[") == 0
        print("PARITY-4WAY-OK")
        """)
    assert "PARITY-4WAY-OK" in out


def test_join_carries_hashes_no_rehash_4way():
    """Post-shuffle join must not re-run the hash chain: the traced join
    jaxpr contains exactly the two pre-shuffle hash sites (left + right),
    each a fused hash_partition, and exactly 2 data AllToAlls."""
    out = _run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import Table, DistTable, HPTMTContext, make_mesh
        from repro.core import table_ops
        mesh = make_mesh((4,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        rng = np.random.default_rng(0)
        lk = rng.permutation(64).astype(np.int32)
        rk = rng.permutation(64).astype(np.int32)[:48]
        l = DistTable.from_local(Table.from_arrays(
            {"k": jnp.asarray(lk), "a": jnp.asarray(lk, jnp.float32)}),
            ctx, capacity=32)
        r = DistTable.from_local(Table.from_arrays(
            {"k": jnp.asarray(rk), "b": jnp.asarray(rk, jnp.float32)}),
            ctx, capacity=32)
        res, ov = table_ops.join(l, r, ["k"], out_capacity=64, ctx=ctx)
        assert int(ov) == 0
        got = sorted(res.to_numpy()["k"].tolist())
        assert got == sorted(set(lk.tolist()) & set(rk.tolist()))
        jaxpr = str(jax.make_jaxpr(
            lambda a, b: table_ops.join(a, b, ["k"], out_capacity=64,
                                        ctx=ctx))(l, r))
        assert jaxpr.count("all_to_all") == 2  # one per side
        # the murmur mix multiplier appears once per hash site: 2 shuffles
        # (h1+h2 fused) and nothing post-shuffle
        assert jaxpr.count("0xcc9e2d51") <= 2, jaxpr.count("0xcc9e2d51")
        print("JOIN-CARRY-OK")
        """)
    assert "JOIN-CARRY-OK" in out
