"""Partitioning rules: shape-validated specs on an abstract production mesh
(no devices needed — AbstractMesh supplies axis names/sizes only)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.sharding import axes as am
from repro.sharding.partition import param_spec

def _abstract_mesh(shape, names):
    try:
        return AbstractMesh(shape, names)
    except TypeError:  # jax<=0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, shape)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MP_MESH = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def spec(names, shape, arch="deepseek-67b", mesh=MESH):
    return param_spec(tuple(names), tuple(shape), get_config(arch), mesh)


def test_attention_tp_fsdp():
    cfg = get_config("deepseek-67b")
    # wq (D, H*Dh): fsdp × heads — 64 heads divide 16
    s = spec(["decoder", "layer_0", "mixer", "wq"], (19, 8192, 8192))
    assert s == P(None, "data", "model")
    # wk: the flattened kv projection dim (8 kv × 128 dh = 1024) divides the
    # model axis, so the parameter shards even though 8 heads alone wouldn't
    s = spec(["decoder", "layer_0", "mixer", "wk"], (19, 8192, 1024))
    assert s == P(None, "data", "model")
    # wo transposed placement
    s = spec(["decoder", "layer_0", "mixer", "wo"], (19, 8192, 8192))
    assert s == P(None, "model", "data")


def test_embed_d_sharded_not_vocab():
    s = spec(["embed"], (102400, 8192))
    assert s == P(None, "model")
    s = spec(["lm_head"], (8192, 102400))
    assert s == P("data", "model")


def test_moe_ep_vs_tp_fallback():
    # jamba: 16 experts % 16 == 0 → EP over model
    s = spec(["decoder", "layer_1", "ffn", "w_gate"], (4, 16, 4096, 14336),
             arch="jamba-v0.1-52b")
    assert s[1] == "model"
    # mixtral: 8 experts, 16-way model axis → expert-internal TP on ff
    s = spec(["decoder", "layer_0", "ffn", "w_gate"], (32, 8, 4096, 14336),
             arch="mixtral-8x7b")
    assert s[1] is None and s[3] == "model"


def test_mamba_inner_sharding():
    s = spec(["decoder", "layer_0", "mixer", "in_proj"], (4, 4096, 16384),
             arch="jamba-v0.1-52b")
    assert s == P(None, "data", "model")
    s = spec(["decoder", "layer_0", "mixer", "a_log"], (4, 8192, 16),
             arch="jamba-v0.1-52b")
    assert s == P(None, "model", None)


def test_norm_scales_replicated():
    s = spec(["decoder", "layer_0", "mixer", "norm", "scale"], (19, 8192))
    assert s == P(None, None)


def test_indivisible_dims_drop_axis():
    # smollm: 15 heads × 64 dh = 960 — divisible by 16 as a flat dim, so
    # the parameter still shards; a truly indivisible dim is dropped:
    s = spec(["decoder", "layer_0", "mixer", "wq"], (32, 960, 960),
             arch="smollm-360m")
    assert s[2] == "model"
    s = spec(["decoder", "layer_0", "mixer", "wq"], (32, 8192, 1000))
    assert s[2] is None  # 1000 % 16 != 0 → replicated


def test_spec_for_dedups_axes():
    with am.logical_binding(None, {"batch": ("pod", "data"),
                                   "heads": "model"}):
        s = am.spec_for(["batch", "heads", None])
        assert s == P(("pod", "data"), "model", None)


def test_cell_rules_long_context():
    from repro.configs import SHAPES
    from repro.launch.cells import cell_rules
    cfg = get_config("jamba-v0.1-52b")
    rules = cell_rules(cfg, SHAPES["long_500k"])
    assert rules["batch"] is None      # B=1: nothing to data-parallel


def test_cell_skip_rules():
    from repro.configs import SHAPES, cell_is_runnable
    ok, _ = cell_is_runnable(get_config("deepseek-67b"), SHAPES["long_500k"])
    assert not ok                       # pure full attention
    ok, _ = cell_is_runnable(get_config("mixtral-8x7b"), SHAPES["long_500k"])
    assert ok                           # SWA bounds the window
    ok, _ = cell_is_runnable(get_config("xlstm-125m"), SHAPES["long_500k"])
    assert ok                           # attention-free
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in ("deepseek-67b", "whisper-medium", "internvl2-76b"):
            ok, _ = cell_is_runnable(get_config(arch), SHAPES[shape])
            assert ok


def test_roofline_collective_parser():
    from repro.launch.roofline import parse_collectives
    hlo = """
      %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}
      %ar = f32[256]{0} all-reduce(%y), replica_groups=[2,8]<=[16]
      %aa = bf16[8,64]{1,0} all-to-all(%z), replica_groups={{0,1}}
      %done = bf16[16,1024]{1,0} all-gather-done(%ag)
    """
    stats = parse_collectives(hlo)
    assert stats.counts == {"all-gather": 1, "all-reduce": 1,
                            "all-to-all": 1}
    assert stats.bytes_by_kind["all-gather"] == 16 * 1024 * 2
    assert stats.bytes_by_kind["all-reduce"] == 256 * 4
    assert stats.cost_s > 0
