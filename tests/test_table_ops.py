"""Table operators (paper Tables II/III, Fig 1/2) vs numpy oracles,
including hypothesis property tests on relational invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env may lack hypothesis: skip only @given tests
    from conftest import given, settings, st

from repro.core import DistTable, Table, local_context, table_ops
from repro.core.operator import Abstraction, list_operators

CTX = local_context()


def make_dt(cols, capacity=None):
    t = Table.from_arrays({k: jnp.asarray(v) for k, v in cols.items()},
                          capacity=capacity)
    return DistTable.from_local(t, CTX)


# ---------------------------------------------------------------------------
# operator inventory — the paper's tables must be fully covered
# ---------------------------------------------------------------------------
def test_operator_registry_covers_paper_tables():
    names = {o.name for o in list_operators(Abstraction.TABLE)}
    for op in ("select", "project", "union", "difference", "cartesian",
               "intersect", "join", "orderby", "aggregate", "groupby",
               "shuffle"):
        assert f"table.{op}" in names, f"missing paper operator {op}"
    array_names = {o.name for o in list_operators(Abstraction.ARRAY)}
    for op in ("broadcast", "gather", "allgather", "scatter", "alltoall",
               "reduce", "allreduce", "reduce_scatter"):
        assert f"array.{op}" in array_names


# ---------------------------------------------------------------------------
# unit tests
# ---------------------------------------------------------------------------
def test_select_project():
    dt = make_dt({"a": np.arange(10, dtype=np.int32),
                  "b": np.arange(10, dtype=np.float32)})
    out = table_ops.select(dt, lambda c: c["a"] >= 5, ctx=CTX)
    got = out.to_numpy()
    assert np.array_equal(got["a"], np.arange(5, 10))
    proj = table_ops.project(out, ["b"], ctx=CTX)
    assert proj.column_names == ("b",)


def test_join_inner_and_left():
    l = make_dt({"k": np.array([1, 2, 3, 4], np.int32),
                 "a": np.array([1., 2, 3, 4], np.float32)})
    r = make_dt({"k": np.array([2, 4, 6], np.int32),
                 "b": np.array([20., 40, 60], np.float32)})
    inner, ov = table_ops.join(l, r, ["k"], ctx=CTX)
    assert int(ov) == 0
    got = inner.to_numpy()
    order = np.argsort(got["k"])
    assert np.array_equal(got["k"][order], [2, 4])
    assert np.array_equal(got["b"][order], [20., 40.])

    left, _ = table_ops.join(l, r, ["k"], how="left", ctx=CTX)
    got = left.to_numpy()
    assert len(got["k"]) == 4
    assert np.array_equal(np.sort(got["k"]), [1, 2, 3, 4])
    unmatched = got["b"][~got["_matched"]]
    assert np.all(unmatched == 0)


def test_join_duplicate_right_keys():
    l = make_dt({"k": np.array([1, 2], np.int32),
                 "a": np.array([1., 2.], np.float32)})
    r = make_dt({"k": np.array([2, 2, 2], np.int32),
                 "b": np.array([5., 6., 7.], np.float32)})
    out, ov = table_ops.join(l, r, ["k"], max_matches=3, out_capacity=8,
                             ctx=CTX)
    got = out.to_numpy()
    assert int(ov) == 0
    assert np.array_equal(np.sort(got["b"]), [5., 6., 7.])
    # bounded fan-out counts overflow
    out2, ov2 = table_ops.join(l, r, ["k"], max_matches=2, out_capacity=8,
                               ctx=CTX)
    assert len(out2.to_numpy()["b"]) == 2


def test_groupby_aggregate():
    dt = make_dt({"k": np.array([3, 1, 3, 1, 3], np.int32),
                  "v": np.array([1., 2, 3, 4, 5], np.float32)})
    out, ov = table_ops.groupby_aggregate(
        dt, ["k"], [("v", "sum"), ("v", "min"), ("v", "max"),
                    ("v", "mean"), ("v", "count")], ctx=CTX)
    got = out.to_numpy()
    order = np.argsort(got["k"])
    assert np.array_equal(got["k"][order], [1, 3])
    np.testing.assert_allclose(got["v_sum"][order], [6, 9])
    np.testing.assert_allclose(got["v_min"][order], [2, 1])
    np.testing.assert_allclose(got["v_max"][order], [4, 5])
    np.testing.assert_allclose(got["v_mean"][order], [3, 3])
    np.testing.assert_allclose(got["v_count"][order], [2, 3])


def test_orderby_desc():
    dt = make_dt({"v": np.array([3., 1., 5., 2.], np.float32)})
    out, _ = table_ops.orderby(dt, "v", ascending=False, ctx=CTX)
    np.testing.assert_allclose(out.to_numpy()["v"], [5, 3, 2, 1])


def test_cartesian():
    a = make_dt({"x": np.array([1, 2], np.int32)})
    b = make_dt({"y": np.array([10, 20, 30], np.int32)})
    out = table_ops.cartesian(a, b, ctx=CTX)
    got = out.to_numpy()
    assert len(got["a_x"]) == 6
    pairs = set(zip(got["a_x"].tolist(), got["b_y"].tolist()))
    assert pairs == {(i, j) for i in (1, 2) for j in (10, 20, 30)}


def test_aggregate_scalar():
    dt = make_dt({"v": np.array([1., 2., 3., 4.], np.float32)})
    assert float(table_ops.aggregate(dt, "v", "sum", ctx=CTX)) == 10.0
    assert float(table_ops.aggregate(dt, "v", "mean", ctx=CTX)) == 2.5
    assert float(table_ops.aggregate(dt, "v", "max", ctx=CTX)) == 4.0
    assert float(table_ops.aggregate(dt, "v", "count", ctx=CTX)) == 4.0


# ---------------------------------------------------------------------------
# property tests (hypothesis): relational invariants
# ---------------------------------------------------------------------------
small_ints = st.lists(st.integers(0, 31), min_size=1, max_size=40)


@settings(max_examples=25, deadline=None)
@given(a=small_ints, b=small_ints)
def test_union_property(a, b):
    """union(A,B) row-set == set(A) | set(B) (paper Table II)."""
    ta = make_dt({"x": np.array(a, np.int32)})
    tb = make_dt({"x": np.array(b, np.int32)})
    out, ov = table_ops.union(ta, tb, ctx=CTX)
    assert int(ov) == 0
    got = sorted(out.to_numpy()["x"].tolist())
    assert got == sorted(set(a) | set(b))


@settings(max_examples=25, deadline=None)
@given(a=small_ints, b=small_ints)
def test_difference_intersect_property(a, b):
    ta = make_dt({"x": np.array(a, np.int32)})
    tb = make_dt({"x": np.array(b, np.int32)})
    diff, _ = table_ops.difference(ta, tb, ctx=CTX)
    got = diff.to_numpy()["x"].tolist()
    expected = [v for v in a if v not in set(b)]
    assert sorted(got) == sorted(expected)
    inter, _ = table_ops.intersect(ta, tb, ctx=CTX)
    got_i = sorted(inter.to_numpy()["x"].tolist())
    assert got_i == sorted(set(a) & set(b))


@settings(max_examples=25, deadline=None)
@given(keys=st.lists(st.integers(0, 15), min_size=1, max_size=32),
       vals=st.lists(st.floats(-100, 100, width=32), min_size=1,
                     max_size=32))
def test_groupby_sum_matches_numpy(keys, vals):
    n = min(len(keys), len(vals))
    keys, vals = np.array(keys[:n], np.int32), np.array(vals[:n], np.float32)
    dt = make_dt({"k": keys, "v": vals})
    out, _ = table_ops.groupby_aggregate(dt, ["k"], [("v", "sum")], ctx=CTX)
    got = out.to_numpy()
    expected = {k: vals[keys == k].sum() for k in set(keys.tolist())}
    assert set(got["k"].tolist()) == set(expected)
    for k, s in zip(got["k"], got["v_sum"]):
        np.testing.assert_allclose(s, expected[int(k)], rtol=1e-4,
                                   atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(vals=st.lists(st.integers(-1000, 1000), min_size=1, max_size=50))
def test_orderby_property(vals):
    dt = make_dt({"v": np.array(vals, np.int32)})
    out, ov = table_ops.orderby(dt, "v", ctx=CTX)
    assert int(ov) == 0
    got = out.to_numpy()["v"]
    assert np.array_equal(got, np.sort(vals))


@settings(max_examples=20, deadline=None)
@given(lk=st.lists(st.integers(0, 20), min_size=1, max_size=20, unique=True),
       rk=st.lists(st.integers(0, 20), min_size=1, max_size=20, unique=True))
def test_join_property(lk, rk):
    l = make_dt({"k": np.array(lk, np.int32),
                 "a": np.array(lk, np.float32)})
    r = make_dt({"k": np.array(rk, np.int32),
                 "b": np.array(rk, np.float32) * 2})
    out, ov = table_ops.join(l, r, ["k"], out_capacity=64, ctx=CTX)
    assert int(ov) == 0
    got = sorted(out.to_numpy()["k"].tolist())
    assert got == sorted(set(lk) & set(rk))


def test_shuffle_preserves_rows():
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 100, 50).astype(np.int32)
    dt = make_dt({"x": vals})
    out, ov = table_ops.shuffle(dt, ["x"], ctx=CTX)
    assert int(ov) == 0
    assert sorted(out.to_numpy()["x"].tolist()) == sorted(vals.tolist())
