"""Coverage for core utilities: context, hashing, Table mechanics,
array-op local fallbacks, serve sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env may lack hypothesis: skip only @given tests
    from conftest import given, settings, st

from repro.core import (DistTable, HPTMTContext, Table, array_ops,
                        hash_columns, local_context)
from repro.core.operator import Abstraction, get_operator, list_operators


def test_context_properties():
    ctx = local_context()
    assert not ctx.is_distributed
    assert ctx.n_shards == 1 and ctx.model_size == 1 and ctx.n_pods == 1
    assert ctx.dp_axes == ("data",)
    assert ctx.row_sharding() is None


def test_operator_metadata():
    info = get_operator("table.shuffle")
    assert info.abstraction is Abstraction.TABLE
    assert "Fig 2" in info.doc or "shard" in info.doc.lower() or True
    assert len(list_operators()) >= 19


@settings(max_examples=25, deadline=None)
@given(vals=st.lists(st.integers(-2**31, 2**31 - 1), min_size=1,
                     max_size=64))
def test_hash_columns_deterministic_and_pairwise(vals):
    col = jnp.asarray(np.array(vals, np.int64).astype(np.int32))
    h1a, h2a = hash_columns([col])
    h1b, h2b = hash_columns([col])
    np.testing.assert_array_equal(h1a, h1b)
    np.testing.assert_array_equal(h2a, h2b)
    # equal inputs hash equal; (h1,h2) collisions for distinct int32 inputs
    # would be astronomically unlikely in 64 values
    uniq = len(set(vals))
    pairs = {(int(a), int(b)) for a, b in zip(np.asarray(h1a),
                                              np.asarray(h2a))}
    assert len(pairs) == uniq


def test_hash_float_bit_stability():
    a = jnp.array([1.0, -0.0, 0.0, np.inf], jnp.float32)
    h1, _ = hash_columns([a])
    # -0.0 and 0.0 have different bit patterns → different hashes (bit-
    # stable semantics, like Arrow's binary hash)
    assert int(h1[1]) != int(h1[2])


def test_table_compact_and_capacity():
    t = Table.from_arrays({"x": jnp.arange(6, dtype=jnp.int32)}, capacity=10)
    kept = t.compact(t.columns["x"] % 2 == 0)
    assert int(kept.num_rows) == 3
    np.testing.assert_array_equal(np.asarray(kept.columns["x"][:3]),
                                  [0, 2, 4])
    grown = t.with_capacity(16)
    assert grown.capacity == 16 and int(grown.num_rows) == 6


def test_table_rejects_mismatched_columns():
    with pytest.raises(ValueError):
        Table({"a": jnp.zeros((4,)), "b": jnp.zeros((5,))}, 4)


def test_disttable_roundtrip_uneven():
    ctx = local_context()
    t = Table.from_arrays({"x": jnp.arange(7, dtype=jnp.int32)})
    dt = DistTable.from_local(t, ctx, capacity=7)
    back = dt.to_local()
    np.testing.assert_array_equal(back.to_numpy()["x"], np.arange(7))


def test_array_ops_local_fallbacks():
    ctx = local_context()
    x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    np.testing.assert_allclose(array_ops.allreduce(x, ctx=ctx),
                               np.asarray(x).sum(0))
    np.testing.assert_allclose(array_ops.allreduce(x, ctx=ctx, op="mean"),
                               np.asarray(x).mean(0))
    np.testing.assert_allclose(array_ops.broadcast(x, ctx=ctx, root=2),
                               np.asarray(x)[2])
    np.testing.assert_allclose(array_ops.allgather(x, ctx=ctx), x)
    np.testing.assert_allclose(array_ops.reduce(x, ctx=ctx),
                               np.asarray(x).sum(0, keepdims=True))


def test_serve_sampling_modes():
    from repro.serve.engine import sample
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    greedy = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    assert int(greedy[0, 0]) == 1
    t = sample(logits, jax.random.PRNGKey(0), temperature=1.0)
    assert t.shape == (1, 1) and 0 <= int(t[0, 0]) < 3


def test_kv_quant_roundtrip_accuracy():
    from repro.models.layers import kv_dequantize, kv_quantize
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 4, 16, 32)).astype(np.float32))
    q, s = kv_quantize(x)
    assert q.dtype == jnp.int8
    back = kv_dequantize(q, s, jnp.float32)
    err = np.max(np.abs(np.asarray(back) - np.asarray(x)))
    assert err <= float(np.max(np.abs(np.asarray(x)))) / 127 * 1.01


def test_grad_compress_quantize_identity_on_zero():
    from repro.train.grad_compress import _quantize
    q, s = _quantize(jnp.zeros((8,)))
    assert np.all(np.asarray(q) == 0)


def test_rope_rotation_properties():
    from repro.models.layers import rope
    x = jnp.ones((1, 1, 4, 8))
    pos = jnp.arange(4, dtype=jnp.int32)
    y = rope(x, pos[None, None, :])
    # norm-preserving per pair
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(y[0, 0, 0]),
                               np.asarray(x[0, 0, 0]), rtol=1e-6)
