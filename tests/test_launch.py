"""Launch layer: mesh, input specs, roofline math, cell plumbing
(all device-free: AbstractMesh / pure functions)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import roofline as rl
from repro.launch.cells import (input_specs, roofline_config,
                                slstm_flops_correction)


def test_input_specs_shapes():
    cfg = get_config("phi3-mini-3.8b")
    s = input_specs(cfg, SHAPES["train_4k"])
    assert s["tokens"].shape == (256, 4096)
    assert s["labels"].shape == (256, 4096)
    s = input_specs(cfg, SHAPES["decode_32k"])
    assert s["token"].shape == (128, 1)
    assert s["pos"].shape == (1,)


def test_input_specs_vlm_prefix():
    cfg = get_config("internvl2-76b")
    s = input_specs(cfg, SHAPES["train_4k"])
    # image patches replace the first frontend_seq backbone positions
    assert s["tokens"].shape == (256, 4096 - cfg.frontend_seq)
    assert s["frontend"].shape == (256, cfg.frontend_seq, cfg.d_model)


def test_input_specs_audio():
    cfg = get_config("whisper-medium")
    s = input_specs(cfg, SHAPES["prefill_32k"])
    assert s["frontend"].shape == (32, 1500, 1024)
    assert "labels" not in s


def test_roofline_config_depth_scaling():
    cfg = get_config("deepseek-67b")
    r1 = roofline_config(cfg, 1)
    r2 = roofline_config(cfg, 2)
    assert r1.n_layers == cfg.group_size
    assert r2.n_layers == 2 * cfg.group_size
    assert r1.scan_unroll and r1.attn_q_chunk > 1_000_000
    w = get_config("whisper-medium")
    assert roofline_config(w, 2).n_encoder_layers == 2


def test_slstm_correction_only_for_slstm():
    assert slstm_flops_correction(get_config("phi3-mini-3.8b"),
                                  SHAPES["train_4k"], 16) == 0
    x = slstm_flops_correction(get_config("xlstm-125m"),
                               SHAPES["train_4k"], 16)
    assert x > 0
    # decode: single step — nothing missing
    assert slstm_flops_correction(get_config("xlstm-125m"),
                                  SHAPES["decode_32k"], 16) == 0


def test_model_flops_conventions():
    cfg = get_config("mixtral-8x7b")
    tr = rl.model_flops_for(cfg, SHAPES["train_4k"])
    pf = rl.model_flops_for(cfg, SHAPES["prefill_32k"])
    dc = rl.model_flops_for(cfg, SHAPES["decode_32k"])
    n_act = cfg.active_param_count()
    assert tr == pytest.approx(6 * n_act * 256 * 4096)
    assert pf == pytest.approx(2 * n_act * 32 * 32768)
    assert dc == pytest.approx(2 * n_act * 128)
    # MoE: active < total
    assert cfg.active_param_count() < cfg.param_count()


def test_active_params_mixtral_magnitude():
    cfg = get_config("mixtral-8x7b")
    assert 40e9 < cfg.param_count() < 55e9       # ~47B total
    assert 10e9 < cfg.active_param_count() < 16e9  # ~13B active


def test_roofline_terms_and_bottleneck():
    colls = rl.CollectiveStats({"all-reduce": 2}, {"all-reduce": 10 ** 9},
                               cost_s=0.5)
    r = rl.Roofline(flops=197e12, hbm_bytes=819e9 / 4, collectives=colls,
                    n_chips=256, model_flops=197e12 * 256 * 0.5)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.step_s == pytest.approx(1.0)
    assert r.mfu == pytest.approx(0.5)


def test_shape_bytes_parser():
    assert rl._shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert rl._shape_bytes("(f32[8]{0}, s32[4]{0})") == 8 * 4 + 4 * 4
    assert rl._shape_bytes("pred[10]") == 10


def test_make_production_mesh_requires_devices():
    # only 1 host device in the test process: building must fail loudly
    from repro.launch.mesh import make_production_mesh
    if len(jax.devices()) < 256:
        with pytest.raises(Exception):
            make_production_mesh()
