"""Checkpoint/restart + workflow fault tolerance (paper §VII-D/E/F)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.workflow.engine import (StragglerMonitor, Task, WorkflowEngine,
                                   WorkflowError)


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(3, tree)
    assert mgr.latest_step() == 3
    restored = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 tree, restored)


def test_checkpoint_latest_wins_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    t2 = jax.tree.map(lambda x: x + 1, t)
    mgr.save(2, t2)
    restored = mgr.restore(jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(restored["a"], np.asarray(t2["a"]))
    # both steps retained; LATEST points at 2
    assert sorted(os.listdir(tmp_path))[:2] == ["LATEST", "step_1"]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    t = _tree()
    mgr.save(7, t)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = {"a": jnp.zeros((5, 5)), "nested": {"b": jnp.zeros((4,),
                                                             jnp.int32)}}
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_trainer_resume_from_checkpoint(tmp_path):
    """Kill-and-restart: the loop resumes from the last snapshot."""
    from repro.configs import get_config, reduced_config
    from repro.data.pipeline import batch_iterator
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import LoopConfig, train_loop
    import numpy as np

    cfg = reduced_config(get_config("smollm-360m"))
    tcfg = TrainConfig(optimizer=OptimizerConfig(warmup_steps=1,
                                                 total_steps=20))
    stream = np.arange(500) % cfg.vocab_size
    logs = []
    loop = LoopConfig(total_steps=6, log_every=2, checkpoint_every=3,
                      checkpoint_dir=str(tmp_path))
    train_loop(cfg, tcfg, loop, batch_iterator(stream, 2, 16),
               log_fn=logs.append)
    # "crash" after step 6; resume to 8
    loop2 = LoopConfig(total_steps=8, log_every=2, checkpoint_every=3,
                       checkpoint_dir=str(tmp_path))
    logs2 = []
    train_loop(cfg, tcfg, loop2, batch_iterator(stream, 2, 16),
               log_fn=logs2.append)
    assert any("resumed from checkpoint step 6" in l for l in logs2)


# ---------------------------------------------------------------------------
# workflow engine
# ---------------------------------------------------------------------------
def test_workflow_dag_order_and_dataflow():
    calls = []
    wf = WorkflowEngine()
    wf.add(Task("a", lambda: calls.append("a") or 1))
    wf.add(Task("b", lambda a: calls.append("b") or a + 1, deps=("a",)))
    wf.add(Task("c", lambda a, b: calls.append("c") or a + b,
                deps=("a", "b")))
    res = wf.run()
    assert res["c"] == 3
    assert calls.index("a") < calls.index("b") < calls.index("c")


def test_workflow_retries_then_succeeds():
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("transient fault")
        return "ok"

    wf = WorkflowEngine()
    wf.add(Task("flaky", flaky, retries=3))
    assert wf.run()["flaky"] == "ok"
    assert attempts["n"] == 3


def test_workflow_fails_after_exhausted_retries():
    wf = WorkflowEngine()
    wf.add(Task("dead", lambda: 1 / 0, retries=1))
    with pytest.raises(WorkflowError):
        wf.run()


def test_workflow_journal_resume(tmp_path):
    journal = str(tmp_path / "journal.json")
    calls = []
    wf = WorkflowEngine(journal)
    wf.add(Task("prep", lambda: calls.append("prep")))
    wf.add(Task("train", lambda prep: calls.append("train"), deps=("prep",)))
    wf.run()
    assert calls == ["prep", "train"]
    # a new engine (restart) skips journaled tasks — workflow-level FT
    wf2 = WorkflowEngine(journal)
    wf2.add(Task("prep", lambda: calls.append("prep2")))
    wf2.add(Task("train", lambda prep: calls.append("train2"),
                 deps=("prep",)))
    wf2.run()
    assert calls == ["prep", "train"]


def test_workflow_cycle_detection():
    wf = WorkflowEngine()
    wf.add(Task("x", lambda y=None: None, deps=("y",)))
    wf.add(Task("y", lambda x=None: None, deps=("x",)))
    with pytest.raises(WorkflowError):
        wf.run()


def test_straggler_monitor():
    mon = StragglerMonitor(window=20, threshold=2.0)
    flagged = [mon.record(0.1) for _ in range(10)]
    assert not any(flagged)
    assert mon.record(0.5) is True          # 5× median
    assert mon.record(0.1) is False
