"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes as mandated."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env may lack hypothesis: skip only @given tests
    from conftest import given, settings, st

from repro.core.table import _as_u32
from repro.kernels.flash_attention import kernel as fk, ref as fr
from repro.kernels.hash_partition import kernel as hk, ref as hr
from repro.kernels.segment_reduce import kernel as sk, ref as sr

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # b, hq, hkv, sq, sk, d, causal, window, q_offset
    (2, 4, 2, 128, 128, 64, True, None, 0),
    (1, 8, 8, 100, 100, 32, True, None, 0),      # ragged (non-multiple)
    (1, 4, 1, 64, 256, 64, False, None, 0),      # MQA, bidirectional
    (2, 2, 2, 1, 512, 64, True, None, 511),      # decode
    (1, 4, 2, 256, 256, 64, True, 64, 0),        # sliding window
    (1, 2, 2, 1, 384, 128, True, 128, 383),      # SWA decode
    (1, 1, 1, 16, 16, 128, True, None, 0),       # tiny
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    b, hq, hkv, sq, sk_, d, causal, window, qoff = case
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, sk_, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, sk_, d)), dtype)
    got = fk.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                    q_offset=qoff, interpret=True,
                                    block_q=64, block_k=64)
    exp = fr.flash_attention(q, k, v, causal=causal, window=window,
                             q_offset=qoff)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_kv_len_mask():
    q = jnp.asarray(RNG.normal(size=(1, 2, 8, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    got = fk.flash_attention_pallas(q, k, v, causal=False, kv_len=50,
                                    interpret=True, block_q=8, block_k=32)
    exp = fr.flash_attention(q, k, v, causal=False, kv_len=50)
    np.testing.assert_allclose(got, exp, rtol=2e-4, atol=2e-4)


def test_flash_matches_model_attend():
    """Kernel semantics == the XLA model path (layers.attend)."""
    from repro.models.layers import attend
    b, hq, hkv, s, d = 1, 4, 2, 96, 32
    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    got_xla = attend(q, k, v, q_pos=pos, kv_pos=pos, causal=True, q_chunk=32)
    got_pl = fk.flash_attention_pallas(q, k, v, causal=True, interpret=True,
                                       block_q=32, block_k=32)
    np.testing.assert_allclose(got_xla, got_pl, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# segment reduce
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("n,s,bn,bs", [
    (1000, 37, 256, 128), (64, 8, 64, 64), (513, 100, 128, 64),
])
def test_segment_reduce_vs_ref(op, n, s, bn, bs):
    vals = jnp.asarray(RNG.normal(size=n), jnp.float32)
    segs = jnp.asarray(np.sort(RNG.integers(0, s, n)).astype(np.int32))
    got = sk.segment_reduce_pallas(vals, segs, s, op, interpret=True,
                                   block_n=bn, block_s=bs)
    exp = sr.segment_reduce(vals, segs, s, op)
    np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(ids=st.lists(st.integers(0, 9), min_size=1, max_size=64))
def test_segment_reduce_property(ids):
    vals = jnp.ones((len(ids),), jnp.float32)
    segs = jnp.asarray(np.array(sorted(ids), np.int32))
    got = sk.segment_reduce_pallas(vals, segs, 10, "sum", interpret=True,
                                   block_n=32, block_s=16)
    counts = np.bincount(np.array(ids), minlength=10)
    np.testing.assert_allclose(got, counts)


def test_segment_reduce_out_of_range_dropped():
    vals = jnp.array([1., 2., 3.], jnp.float32)
    segs = jnp.array([0, 99, 1], jnp.int32)
    got = sk.segment_reduce_pallas(vals, segs, 2, "sum", interpret=True,
                                   block_n=8, block_s=8)
    np.testing.assert_allclose(got, [1., 3.])


# ---------------------------------------------------------------------------
# hash partition
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,k,p", [(500, 2, 16), (100, 1, 3), (1025, 3, 64)])
def test_hash_partition_vs_ref(n, k, p):
    cols = []
    for i in range(k):
        if i % 2:
            cols.append(jnp.asarray(RNG.normal(size=n), jnp.float32))
        else:
            cols.append(jnp.asarray(RNG.integers(0, 1000, n), jnp.int32))
    valid = jnp.asarray(RNG.random(n) < 0.8)
    keys = jnp.stack([_as_u32(c) for c in cols], axis=1)
    dg, hg = hk.hash_partition_pallas(keys, valid, p, interpret=True,
                                      block_n=128)
    de, he = hr.hash_partition(cols, p, valid)
    np.testing.assert_array_equal(dg, de)
    np.testing.assert_array_equal(hg, he)
    # histogram counts exactly the valid rows
    assert int(hg.sum()) == int(valid.sum())


def test_hash_partition_determinism_and_balance():
    n, p = 4096, 16
    col = jnp.arange(n, dtype=jnp.int32)
    valid = jnp.ones((n,), bool)
    d1, h1 = hr.hash_partition([col], p, valid)
    d2, _ = hr.hash_partition([col], p, valid)
    np.testing.assert_array_equal(d1, d2)
    # murmur-style hash should balance sequential keys decently
    assert int(h1.max()) < 2 * n // p
